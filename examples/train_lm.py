"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps with checkpointing (resumable).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

import jax

from repro.configs import ShapeSpec
from repro.configs.base import ArchConfig
from repro.checkpointing.checkpoint import AsyncSaver, latest_step, restore
from repro.data.pipeline import DataConfig, Pipeline
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

# ~100M params: 12L × d512 × ff2048, 32k vocab
CFG_100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=32000, qkv_bias=True,
    rope_theta=10_000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    shape = ShapeSpec("train", args.seq, args.batch, "train")
    opt = OptConfig(peak_lr=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                             max_seq=args.seq)
    start = latest_step(args.ckpt) or 0
    if start:
        state = restore(args.ckpt, start, state)
        print(f"[train_lm] resumed at step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt))
    pipe = Pipeline(cfg, shape, DataConfig(seed=42), start_step=start)
    saver = AsyncSaver()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, next(pipe))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if (step + 1) % 50 == 0:
            saver.save_async(args.ckpt, step + 1, state)
    saver.wait()
    pipe.close()


if __name__ == "__main__":
    main()
