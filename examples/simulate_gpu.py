"""Paper-reproduction driver: simulate benchmark suites on the modeled
RTX 3080 Ti, report per-workload cycles/IPC/cache stats, and verify the
determinism property on every one.

Run:  PYTHONPATH=src python examples/simulate_gpu.py [--suite rodinia]
"""
import argparse
import time

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import SUITES, make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="lonestar",
                    choices=sorted(SUITES) + ["all"])
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--check-determinism", action="store_true")
    args = ap.parse_args()

    cfg = RTX3080TI
    names = (sum(SUITES.values(), []) if args.suite == "all"
             else SUITES[args.suite])
    print(f"{'workload':12s} {'cycles':>9s} {'ipc':>7s} {'ctas':>6s} "
          f"{'l1 hit%':>8s} {'dram':>8s} {'wall s':>7s}")
    for name in names:
        w = make_workload(name, scale=args.scale)
        t0 = time.time()
        st = simulate(w, cfg, make_sm_runner(cfg, "vmap"),
                      max_cycles=1 << 17)
        out = S.finalize(st)
        if args.check_determinism:
            ref = S.finalize(simulate(w, cfg, make_sm_runner(cfg, "seq"),
                                      max_cycles=1 << 17))
            assert S.comparable(out) == S.comparable(ref), name
        l1 = out["l1_hit"] / max(out["l1_hit"] + out["l1_miss"], 1) * 100
        print(f"{name:12s} {out['cycles']:9d} {out['ipc']:7.2f} "
              f"{out['ctas_launched']:6d} {l1:8.1f} {out['dram_req']:8d} "
              f"{time.time() - t0:7.1f}")


if __name__ == "__main__":
    main()
