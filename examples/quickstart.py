"""Quickstart: the three faces of the framework in one script.

  1. simulate a GPGPU workload with the deterministic parallel simulator
     (the paper's contribution) and verify sequential ≡ parallel;
  2. train a reduced LM for a few steps;
  3. serve it (prefill + greedy decode).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# ---- 1. deterministic parallel simulation ---------------------------------
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import make_workload

cfg_gpu = RTX3080TI
workload = make_workload("hotspot", scale=0.02)
seq = S.comparable(S.finalize(simulate(
    workload, cfg_gpu, make_sm_runner(cfg_gpu, "seq"), max_cycles=1 << 16)))
par = S.comparable(S.finalize(simulate(
    workload, cfg_gpu, make_sm_runner(cfg_gpu, "vmap"), max_cycles=1 << 16)))
assert seq == par, "determinism violated!"
print(f"[sim] hotspot: {par['cycles']} GPU cycles, "
      f"{par['issued']} instructions — sequential ≡ parallel ✓")

# ---- 2. train a tiny LM -----------------------------------------------------
from repro.configs import ShapeSpec, get_reduced
from repro.data.pipeline import make_batch_np
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

cfg = get_reduced("qwen2-72b")      # same family, toy dims
shape = ShapeSpec("quick", 64, 4, "train")
opt = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=64)
step = jax.jit(make_train_step(cfg, opt))
for i in range(10):
    state, metrics = step(state, make_batch_np(cfg, shape, seed=0, step=i))
print(f"[train] 10 steps, loss={float(metrics['loss']):.3f}")

# ---- 3. serve ---------------------------------------------------------------
from repro.models.factory import generate
from repro.models import factory

prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size, dtype=jnp.int32)
out = generate(state["params"], cfg, prompts, max_new=8)
print(f"[serve] generated: {out[0].tolist()}")
print("quickstart OK")
