"""Batched serving example: prefill a batch of prompts, decode with greedy
sampling, report tokens/s — using the same code paths the multi-pod dry-run
lowers (factory.prefill / factory.decode).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-1.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.factory import generate
from repro.models import factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = factory.init_params(
        key, cfg, max_seq=args.prompt_len + args.max_new)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    # warmup (compile)
    generate(params, cfg, prompts, max_new=2)
    t0 = time.time()
    out = generate(params, cfg, prompts, max_new=args.max_new)
    dt = time.time() - t0
    print(f"[{args.arch}] batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}: {args.batch * args.max_new / dt:.1f} tok/s")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
