"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.sm_issue.kernel import issue_select_pallas
from repro.kernels.sm_issue.ref import issue_select_ref
from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv_ref_stepwise
from repro.sim.config import N_UNITS


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,hd,bq,bk", [(128, 32, 64, 64), (256, 64, 128, 128),
                                        (256, 128, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, s, hd, bq, bk, causal):
    key = jax.random.PRNGKey(s + hd)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, s, hd), jnp.float32).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(o.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("s,hs,chunk", [(64, 32, 32), (128, 64, 64),
                                        (128, 32, 16)])
def test_wkv6_kernel_sweep(s, hs, chunk):
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    shp = (2, s, 2, hs)
    r = jax.random.normal(ks[0], shp) * 0.5
    k = jax.random.normal(ks[1], shp) * 0.5
    v = jax.random.normal(ks[2], shp) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], shp) - 1)
    u = 0.3 * jax.random.normal(ks[4], (2, hs))
    o, st = wkv6_pallas(r, k, v, w, u, chunk=chunk)
    o_ref, st_ref = wkv_ref_stepwise(r, k, v, w, u,
                                     jnp.zeros((2, 2, hs, hs)))
    np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_sm_issue_property(seed):
    rng = np.random.default_rng(seed)
    n_sm, w, sc, L = 4, 8, 2, 16
    args = (jnp.asarray(rng.integers(0, L + 2, (n_sm, w)), jnp.int32),
            jnp.asarray(rng.random((n_sm, w)) < 0.7),
            jnp.asarray(rng.integers(0, 20, (n_sm, w)), jnp.int32),
            jnp.asarray(rng.integers(0, 2, (n_sm, w)), jnp.int32),
            jnp.asarray(rng.random((n_sm, w)) < 0.3),
            jnp.asarray(rng.integers(-1, w, (n_sm, sc)), jnp.int32),
            jnp.asarray(rng.integers(0, 15, (n_sm, sc, N_UNITS)), jnp.int32),
            jnp.asarray(rng.integers(0, 6, (L,)), jnp.int32),
            jnp.asarray(rng.random((L,)) < 0.5),
            int(rng.integers(0, 15)))
    ref = issue_select_ref(*args, n_subcores=sc)
    got = issue_select_pallas(*args, n_subcores=sc)
    assert (np.asarray(ref) == np.asarray(got)).all()
