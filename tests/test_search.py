"""Analytic fast-path surrogate + search-driven DSE (core/analytic.py,
core/search.py, sim/features.py) and the buffer-donation satellite.

Locks the contracts the search layer is built on:

  · seeded determinism — same seed reproduces the full candidate
    sequence, the verified top-k and the final best bit-exactly; a
    different seed explores differently;
  · self-calibration — after fitting on its own verify sweeps, the
    surrogate's in-sample relative error and predicted-vs-measured rank
    correlation clear fixed bounds on a small exhaustive grid;
  · RunPlan search-knob validation;
  · donation — the donating runners free their input state batch
    (no-copy) and produce bit-identical results to the undonated form.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import analytic
from repro.core.plan import RunPlan
from repro.core.search import SearchSpace, search
from repro.core.sweep import batched_init, make_sweep_runner, stack_dyn, sweep
from repro.sim import features as F
from repro.sim.config import TINY, split_config
from repro.workloads import make_workload

MAX_CYCLES = 1 << 14
PLAN = RunPlan(max_cycles=MAX_CYCLES, search_rounds=2, search_topk=4)


@pytest.fixture(scope="module")
def workload():
    return make_workload("nn", scale=0.05)


# ---------------------------------------------------------------------------
# parameter-vector encoding
# ---------------------------------------------------------------------------

def test_encode_decode_roundtrip():
    vec = analytic.encode_config(TINY)
    assert vec.shape == (analytic.N_PARAMS,)
    flat = analytic.decode(vec)
    assert np.array_equal(analytic.encode(flat), vec)
    # decode output is a valid flat override lane for stack_dyn
    scfg, _ = stack_dyn([(split_config(TINY)[0], flat)])
    assert scfg == split_config(TINY)[0]


def test_describe_vec_matches_manifest_lane_format():
    vec = analytic.encode_config(TINY)
    lane = analytic.describe_vec(vec)
    assert lane["scheduler"] == TINY.scheduler
    back = analytic.params_from_lane(lane)
    assert np.array_equal(back, vec)


def test_features_shape_and_finite(workload):
    scfg, _ = split_config(TINY)
    feats = F.workload_features(workload, scfg)
    assert feats.shape == (F.N_FEATURES,)
    assert np.isfinite(feats).all() and (feats >= 0).all()


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

def test_space_bounds_and_sampling():
    space = SearchSpace.from_base(TINY)
    lo = np.asarray(space.lo)
    hi = np.asarray(space.hi)
    assert (lo <= hi).all()
    # icnt_lat floor: the quantum <= icnt_lat machine invariant
    icnt = analytic.P_SCALARS.index("icnt_lat")
    assert lo[icnt] >= TINY.quantum
    rng = np.random.Generator(np.random.PCG64(3))
    cands = space.sample(rng, 64)
    assert ((cands >= lo) & (cands <= hi)).all()
    kids = space.mutate(rng, cands[:4], 32)
    assert ((kids >= lo) & (kids <= hi)).all()


def test_space_sample_triples_override_bounds():
    space = SearchSpace.from_base(TINY, sample_lat=[("fp32", 2, 9)],
                                  sample_disp=[("sfu", 1, 3)])
    from repro.sim.config import class_index
    i = analytic.P_LAT + class_index("fp32")
    assert (space.lo[i], space.hi[i]) == (2, 9)
    j = analytic.P_DISP + class_index("sfu")
    assert (space.lo[j], space.hi[j]) == (1, 3)


def test_space_validation():
    with pytest.raises(ValueError):
        SearchSpace(lo=(0,), hi=(1,))
    good = SearchSpace.from_base(TINY)
    with pytest.raises(ValueError):
        SearchSpace(lo=good.hi, hi=good.lo)


# ---------------------------------------------------------------------------
# RunPlan search knobs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"search_seed": -1},
    {"search_rounds": 0},
    {"search_topk": 0},
    {"max_buckets": 0},
])
def test_plan_rejects_bad_search_knobs(kw):
    with pytest.raises(ValueError):
        RunPlan(**kw)


def test_plan_accepts_search_knobs_and_describes_them():
    p = RunPlan(search_seed=11, search_rounds=5, search_topk=2,
                max_buckets=None)
    d = p.describe()
    assert (d["search_seed"], d["search_rounds"], d["search_topk"]) \
        == (11, 5, 2)
    assert d["max_buckets"] is None


# ---------------------------------------------------------------------------
# seeded search determinism + calibration quality
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def twin_results(workload):
    space = SearchSpace.from_base(TINY)
    kw = dict(plan=PLAN, base=TINY, n_candidates=48, calibrate_from=None)
    return (search(workload, space, seed=7, **kw),
            search(workload, space, seed=7, **kw),
            search(workload, space, seed=8, **kw))


def test_search_same_seed_bit_reproducible(twin_results):
    a, b, _ = twin_results
    assert a.best == b.best
    assert a.best_cycles == b.best_cycles
    assert len(a.verified) == len(b.verified)
    for (va, ca, _), (vb, cb, _) in zip(a.verified, b.verified):
        assert np.array_equal(va, vb)
        assert ca == cb
    # round reports match except the wall-clock fields
    timing = ("analytic_s", "analytic_cands_per_s", "verify_s",
              "verify_lanes_per_s")
    strip = lambda r: {k: v for k, v in r.items() if k not in timing}  # noqa: E731
    assert [strip(r) for r in a.rounds] == [strip(r) for r in b.rounds]


def test_search_different_seed_differs(twin_results):
    a, _, c = twin_results
    assert any(not np.array_equal(va, vc)
               for (va, _, _), (vc, _, _) in zip(a.verified, c.verified))


def test_search_calibration_and_rank_correlation(twin_results):
    """After self-calibrating on its own verify sweeps, the surrogate
    must fit the measured rows tightly (in-sample) and rank them in
    order.  Bounds are loose vs the measured ~2-5% error so timing noise
    never flakes them — they catch a broken basis, not drift."""
    a, _, _ = twin_results
    calib = a.model.calib
    assert calib["n_rows"] == len(a.verified) >= PLAN.search_topk
    assert calib["mean_rel_err"] <= 0.25
    assert calib["rank_corr"] is None or calib["rank_corr"] >= 0.5


def test_search_beats_or_matches_every_verified_lane(twin_results, workload):
    a, _, _ = twin_results
    assert a.best_cycles == min(c for _, c, _ in a.verified)
    # the reported best lane replays to the same measured cycles
    scfg, _ = split_config(TINY)
    res = sweep(workload, [(scfg, a.best)], plan=PLAN)
    assert res.cycles[0] == a.best_cycles


def test_analytic_rank_correlation_on_latency_axis(workload):
    """Fit on alternate points of a single-axis l2_lat sweep, rank the
    held-out points in between.  Interpolation along one physical axis
    is the generalization the linear basis is built for (the search loop
    refits on ALL measured rows each round, so global extrapolation over
    the 21-dim box is deliberately not a contract — see
    test_search_calibration_and_rank_correlation for the in-sample
    bound the search actually relies on)."""
    scfg, _ = split_config(TINY)
    base = analytic.encode_config(TINY)
    i_l2 = analytic.P_SCALARS.index("l2_lat")
    axis = np.stack([base] * 8)
    axis[:, i_l2] = np.arange(4, 36, 4)
    res = sweep(workload, [(scfg, analytic.decode(v)) for v in axis],
                plan=PLAN)
    feats = F.workload_features(workload, scfg)
    measured = np.asarray(res.cycles, np.float64)
    model = analytic.CostModel.fit(
        [(feats, v, c) for v, c in zip(axis[::2], measured[::2])])
    assert model.calib["mean_rel_err"] <= 0.05
    pred = model.predict(feats, axis[1::2])
    corr = analytic.spearman(pred, measured[1::2])
    assert corr is not None and corr >= 0.5, (corr, model.calib)


# ---------------------------------------------------------------------------
# manifest calibration rows
# ---------------------------------------------------------------------------

def test_calibration_rows_roundtrip(tmp_path, workload):
    from repro.core import telemetry as T
    scfg, _ = split_config(TINY)
    feats = F.workload_features(workload, scfg)
    vec = analytic.encode_config(TINY)
    T.write_manifest(
        "search", scfg=scfg, stats=[{"cycles": 1234}],
        lanes=[analytic.describe_vec(vec)],
        extra={"features": feats.tolist()}, out_dir=str(tmp_path))
    rows = analytic.calibration_rows_from_manifests(scfg, str(tmp_path))
    assert len(rows) == 1
    got_f, got_v, got_c = rows[0]
    assert np.allclose(got_f, feats)
    assert np.array_equal(got_v, vec)
    assert got_c == 1234.0
    # a different static shape must contribute nothing
    other = dataclasses.replace(scfg, n_sm=scfg.n_sm * 2)
    assert analytic.calibration_rows_from_manifests(
        other, str(tmp_path)) == []


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------

def test_donated_sweep_frees_input_and_matches_undonated(workload):
    from repro.core.batch import stack_kernels
    scfg, dyn_batch = stack_dyn([TINY, dataclasses.replace(TINY, l2_lat=40)])
    stacked = stack_kernels([k.pack() for k in workload.kernels])

    donating = make_sweep_runner(scfg, max_cycles=MAX_CYCLES, donate=True)
    plain = make_sweep_runner(scfg, max_cycles=MAX_CYCLES, donate=False)

    st = batched_init(scfg, 2)
    out_d = jax.block_until_ready(donating(st, stacked, dyn_batch))
    # every input buffer was consumed — the output aliases it, no copy
    assert all(x.is_deleted() for x in jax.tree_util.tree_leaves(st))

    st2 = batched_init(scfg, 2)
    out_p = jax.block_until_ready(plain(st2, stacked, dyn_batch))
    assert not any(x.is_deleted() for x in jax.tree_util.tree_leaves(st2))

    for a, b in zip(jax.tree_util.tree_leaves(out_d),
                    jax.tree_util.tree_leaves(out_p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sweep_results_unchanged_by_donation_refactor(workload):
    """sweep() (donating runner inside) still equals a solo engine run —
    the golden-equivalence guard for the refactor."""
    from repro.core import stats as S
    from repro.core.engine import simulate
    from repro.core.parallel import make_sm_runner
    cfg = dataclasses.replace(TINY, scheduler="lrr")
    res = sweep(workload, [TINY, cfg], plan=PLAN)
    for i, c in enumerate([TINY, cfg]):
        solo = S.comparable(S.finalize(simulate(
            workload, c, make_sm_runner(c, "vmap"),
            plan=RunPlan(max_cycles=MAX_CYCLES))))
        assert S.comparable(res.stats[i]) == solo
