"""Simulation-as-a-service conformance + soak suite (core/service.py).

The server's determinism contract, executable: every served lane is
bit-identical (``comparable()`` + timeout accounting) to a solo
``simulate()`` run of its (workload, config) pair — regardless of which
strangers it was co-batched with, the arrival order, or where the batch
boundaries fell.  The serving analogue of tests/test_zoo_grid.py.

Plus the service semantics around that contract: admission rejection by
name for CTAs that could never dispatch, malformed submissions rejected
with the offending FIELD named (TraceFormatError style), a seeded
multi-client soak against a live threaded server (nothing starved,
nothing dropped, queue drains), and warm-cache behavior across a server
restart.
"""
import os
import threading

import pytest

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.core.plan import RunPlan
from repro.core.service import ServiceError, SimService, build_job
from repro.sim.config import TINY, split_config
from repro.sim.workloads import resolve_workload, trace_search_dirs
from _hyp import given, settings, st

MAX_CYCLES = 1 << 15
SCALE = 0.02
PLAN = RunPlan(max_cycles=MAX_CYCLES, bucket_by="shape")

# the mixed zoo + trace submission pool every test draws from; distinct
# footprints so shape bucketing has real work to do
SUBS = {
    "zoo": {"workload": "mixed", "scale": SCALE},
    "cfg": {"workload": "reduction_tree", "scale": SCALE,
            "config": {"l2_lat": 64, "scheduler": "lrr"}},
    "trace": {"workload": "trace:vecadd"},
    "grid": {"workload": "streaming_copy", "scale": SCALE,
             "sample": {"n": 2, "lat": [["fp32", 2, 8]]}},
}


def sig(stats):
    return dict(S.comparable(stats), timeouts=stats["timeouts"])


_solo_cache = {}


def solo_sigs(job):
    """Expected per-lane signatures for an admitted job, computed from
    solo ``simulate()`` runs (memoized: the pool reuses pairs)."""
    out = []
    for w, cfg in job.pairs:
        key = (w.name, cfg)
        if key not in _solo_cache:
            _solo_cache[key] = sig(S.finalize(simulate(
                w, cfg, make_sm_runner(cfg, "vmap"),
                plan=RunPlan(max_cycles=MAX_CYCLES))))
        out.append(_solo_cache[key])
    return out


def check_job(job):
    assert job.done and job.error is None, job.response()
    assert [sig(s) for s in job.stats] == solo_sigs(job), job.id


def sync_service(**kw):
    kw.setdefault("plan", PLAN)
    return SimService(base=TINY, start=False, **kw)


# ---------------------------------------------------------------------------
# co-batching invariance: the conformance core
# ---------------------------------------------------------------------------

def test_solo_batch_matches_solo_run():
    svc = sync_service()
    job = svc.submit(SUBS["zoo"])
    assert svc.run_pending() == 1
    check_job(job)
    assert job.latency()["total_s"] >= 0.0


def test_cobatched_with_strangers_identical():
    """The same submission alone, co-batched with three strangers, and
    split across flush boundaries: three bit-identical results."""
    alone = sync_service()
    a = alone.submit(SUBS["zoo"])
    alone.run_pending()

    together = sync_service()
    jobs = [together.submit(SUBS[k]) for k in
            ("zoo", "cfg", "trace", "grid")]
    served = together.run_pending()
    assert served == 4
    assert jobs[0].batch["n_jobs"] == 4 and jobs[0].batch["n_lanes"] == 5

    split = sync_service()
    s1 = split.submit(SUBS["zoo"])
    split.run_pending()                      # boundary between the two
    s2 = [split.submit(SUBS[k]) for k in ("cfg", "trace", "grid")]
    split.run_pending()

    for job in [a] + jobs + [s1] + s2:
        check_job(job)
    assert sig(a.stats[0]) == sig(jobs[0].stats[0]) == sig(s1.stats[0])


def test_lane_quantum_padding_is_live_and_inert():
    """lane_quantum rounds the bucket up by repeating live lanes; the
    duplicates change nothing about any job's result."""
    svc = sync_service(lane_quantum=4)
    jobs = [svc.submit(SUBS[k]) for k in ("zoo", "cfg", "trace")]
    svc.run_pending()
    for job in jobs:
        check_job(job)


def test_arrival_order_irrelevant():
    orders = [("zoo", "cfg", "trace"), ("trace", "zoo", "cfg"),
              ("cfg", "trace", "zoo")]
    results = []
    for order in orders:
        svc = sync_service()
        jobs = {k: svc.submit(SUBS[k]) for k in order}
        svc.run_pending()
        results.append({k: sig(j.stats[0]) for k, j in jobs.items()})
    assert results[0] == results[1] == results[2]
    for job in jobs.values():
        check_job(job)


# ---------------------------------------------------------------------------
# admission + validation: errors name the offending field
# ---------------------------------------------------------------------------

def oversized_trace_text():
    """The bundled vecadd trace with a 512-thread block: 16 warps per
    CTA, twice TINY's 8 warp slots — lowers fine, can never dispatch."""
    for d in trace_search_dirs():
        path = os.path.join(d, "vecadd.trace")
        if os.path.exists(path):
            with open(path) as f:
                return f.read().replace("-block dim = (64,1,1)",
                                        "-block dim = (512,1,1)")
    pytest.skip("bundled vecadd.trace not found")


def test_oversized_cta_rejected_by_name():
    svc = sync_service()
    with pytest.raises(ServiceError, match="could never dispatch"):
        svc.submit({"trace_text": oversized_trace_text()})
    assert svc.stats()["rejected"] == 1
    assert svc.stats()["pending"] == 0


@pytest.mark.parametrize("payload,fieldname", [
    ({}, "workload"),                                    # neither source
    ({"workload": "mixed", "trace_text": "x"}, "workload"),   # both
    ({"workload": "no_such_zoo_name"}, "workload"),
    ({"workload": 7}, "workload"),
    ({"trace_text": ""}, "trace_text"),
    ({"trace_text": "not a trace at all"}, "trace_text"),
    ({"workload": "mixed", "scale": -1}, "scale"),
    ({"workload": "mixed", "scale": True}, "scale"),
    ({"workload": "mixed", "config": {"n_sm": 4}}, "config.n_sm"),
    ({"workload": "mixed", "config": {"l2_lat": 1.5}}, "config.l2_lat"),
    ({"workload": "mixed", "config": {"scheduler": "fifo"}},
     "config.scheduler"),
    ({"workload": "mixed", "config": {"lat_of_class": [1, 2]}},
     "config.lat_of_class"),
    ({"workload": "mixed", "config": 3}, "config"),
    ({"workload": "mixed", "configs": []}, "configs"),
    ({"workload": "mixed", "configs": [{"bogus_knob": 1}]},
     "configs[0].bogus_knob"),
    ({"workload": "mixed", "config": {}, "sample": {"n": 2}}, "sample"),
    ({"workload": "mixed", "sample": {"n": 0}}, "sample.n"),
    ({"workload": "mixed", "sample": {"n": 2, "lat": [["fp32", 2]]}},
     "sample.lat"),
    ({"workload": "mixed", "sample": {"wat": 1}}, "sample"),
    ({"workload": "mixed", "id": 9}, "id"),
    ({"workload": "mixed", "surprise": 1}, "surprise"),
])
def test_malformed_submission_names_field(payload, fieldname):
    svc = sync_service()
    with pytest.raises(ServiceError) as ei:
        svc.submit(payload)
    assert ei.value.field == fieldname
    assert repr(fieldname) in str(ei.value)   # message carries the name
    assert svc.stats()["pending"] == 0


def test_static_shape_override_rejected():
    """Dynamic-key overrides that sneak in a static-shape change are
    impossible by construction (only DYN keys are accepted), and the
    residual guard still runs — build_job on a foreign base raises."""
    import dataclasses
    other = dataclasses.replace(TINY, n_sm=4)
    with pytest.raises(ServiceError, match="StaticConfig shape"):
        build_job({"workload": "mixed", "scale": SCALE},
                  other, split_config(TINY)[0], seq=1)


def test_trace_text_upload_serves():
    """An uploaded trace body (not a registered name) is lowered, served,
    and bit-identical to simulating the lowered workload directly."""
    for d in trace_search_dirs():
        path = os.path.join(d, "vecadd.trace")
        if os.path.exists(path):
            text = open(path).read()
            break
    else:
        pytest.skip("bundled vecadd.trace not found")
    svc = sync_service()
    job = svc.submit({"id": "upload", "trace_text": text})
    svc.run_pending()
    check_job(job)
    assert job.name == "trace:upload"


# ---------------------------------------------------------------------------
# soak: threaded server, multi-client, nothing starved or dropped
# ---------------------------------------------------------------------------

def test_soak_multiclient_threaded():
    """4 client threads × 3 mixed submissions against ONE live server
    (scheduler thread, small batch/deadline so several batches form).
    Every response arrives, none errors, every lane is bit-exact, and
    the queue drains."""
    svc = SimService(base=TINY, plan=PLAN, batch_lanes=4,
                     max_wait_s=0.01, start=True)
    keys = list(SUBS)
    jobs, jobs_lock = [], threading.Lock()

    def client(ci):
        for j in range(3):
            job = svc.submit(dict(SUBS[keys[(ci + j) % len(keys)]],
                                  id=f"c{ci}-{j}"))
            with jobs_lock:
                jobs.append(job)

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert svc.drain(timeout=300.0), svc.stats()
    svc.shutdown(drain=False)

    assert len(jobs) == 12
    for job in jobs:
        assert job.wait(timeout=1.0), f"{job.id} starved"
        check_job(job)
    counters = svc.stats()
    assert counters["served"] == counters["submitted"] == 12
    assert counters["errors"] == 0 and counters["pending"] == 0
    assert counters["batches"] >= 1
    assert {j.id for j in jobs} == \
        {f"c{c}-{j}" for c in range(4) for j in range(3)}


def test_batch_failure_routes_error_to_jobs(monkeypatch):
    """An execution failure mid-batch must answer every affected client,
    not hang them: jobs report status=error, counters record it."""
    svc = SimService(base=TINY, plan=PLAN, batch_lanes=2,
                     max_wait_s=0.01, start=True)

    def boom(*a, **k):
        raise RuntimeError("injected batch failure")
    monkeypatch.setattr("repro.core.service.pair_sweep", boom)
    jobs = [svc.submit(SUBS["zoo"]), svc.submit(SUBS["cfg"])]
    for job in jobs:
        assert job.wait(timeout=30.0)
        assert job.error is not None
        resp = job.response()
        assert resp["ok"] is False and "injected" in resp["error"]
    assert svc.stats()["errors"] == 2
    svc.shutdown(drain=False)


# ---------------------------------------------------------------------------
# warm restart: same cache_dir, new server instance, compile_s == 0.0
# ---------------------------------------------------------------------------

def test_restart_same_cache_dir_reports_warm_hits(tmp_path, monkeypatch):
    """A restarted server (fresh SimService over the same cache_dir and
    plan) serves its first batch off the warm executable caches: the
    batch reports ``compile_s == 0.0`` and an AOT hit."""
    from repro.core import plan as plan_mod
    # allow re-wiring the persistent cache to this test's dir
    monkeypatch.setattr(plan_mod, "_persistent_cache_dir", None)
    plan = RunPlan(max_cycles=MAX_CYCLES, bucket_by="shape",
                   cache_dir=str(tmp_path / "xla-cache"))

    first = sync_service(plan=plan)
    j1 = first.submit(SUBS["zoo"])
    first.run_pending()
    check_job(j1)
    first.shutdown(drain=False)

    second = sync_service(plan=plan)      # the "restart"
    j2 = second.submit(SUBS["zoo"])
    second.run_pending()
    check_job(j2)
    assert j2.batch["compile_s"] == 0.0, j2.batch
    assert j2.batch["aot_cache"] == "hit"
    assert sig(j1.stats[0]) == sig(j2.stats[0])
    assert second.stats()["aot_hits"] >= 1


# ---------------------------------------------------------------------------
# property: random submit/flush interleavings are order-independent
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.lists(st.sampled_from(sorted(SUBS) + ["FLUSH"]),
                min_size=1, max_size=6))
def test_interleaving_order_independent(script):
    """Any interleaving of submissions and batch boundaries — including
    duplicate submissions of the same job — yields the same per-job
    signatures as the solo runs."""
    svc = sync_service()
    jobs = []
    for step in script:
        if step == "FLUSH":
            svc.run_pending()
        else:
            jobs.append((step, svc.submit(SUBS[step])))
    while svc.run_pending():
        pass
    for key, job in jobs:
        check_job(job)
