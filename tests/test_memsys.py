"""Memory-system unit tests: the max-plus queueing recurrence is exact."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.sim.memsys import _lex_sort, _seg_maxplus


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 50),
                          st.integers(1, 5)), min_size=1, max_size=40))
def test_seg_maxplus_matches_loop(items):
    """finish_i = max(arrival_i, finish_{i-1}) + service_i per segment."""
    items.sort(key=lambda x: x[0])
    seg = np.array([x[0] for x in items], np.int32)
    arr = np.array([x[1] for x in items], np.int32)
    srv = np.array([x[2] for x in items], np.int32)
    seg_start = np.ones(len(items), bool)
    seg_start[1:] = seg[1:] != seg[:-1]
    got = np.asarray(_seg_maxplus(jnp.asarray(seg_start), jnp.asarray(srv),
                                  jnp.asarray(arr)))
    finish = {}
    want = []
    for s, a, v in items:
        f = max(a, finish.get(s, 0)) + v
        finish[s] = f
        want.append(f)
    assert (got == np.array(want)).all()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 100)),
                min_size=1, max_size=30))
def test_lex_sort(items):
    p = jnp.asarray([x[0] for x in items], jnp.int32)
    s = jnp.asarray([x[1] for x in items], jnp.int32)
    t = jnp.arange(len(items), dtype=jnp.int32)
    valid = jnp.ones(len(items), bool)
    order = np.asarray(_lex_sort(p, s, t, valid))
    keys = [(items[i][0], items[i][1], i) for i in order]
    assert keys == sorted(keys)
