"""Memory-system unit tests: the max-plus queueing recurrence is exact."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.sim.config import TINY, split_config
from repro.sim.memsys import _lex_sort, _seg_maxplus, mem_phase
from repro.sim.state import init_state


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 50),
                          st.integers(1, 5)), min_size=1, max_size=40))
def test_seg_maxplus_matches_loop(items):
    """finish_i = max(arrival_i, finish_{i-1}) + service_i per segment."""
    items.sort(key=lambda x: x[0])
    seg = np.array([x[0] for x in items], np.int32)
    arr = np.array([x[1] for x in items], np.int32)
    srv = np.array([x[2] for x in items], np.int32)
    seg_start = np.ones(len(items), bool)
    seg_start[1:] = seg[1:] != seg[:-1]
    got = np.asarray(_seg_maxplus(jnp.asarray(seg_start), jnp.asarray(srv),
                                  jnp.asarray(arr)))
    finish = {}
    want = []
    for s, a, v in items:
        f = max(a, finish.get(s, 0)) + v
        finish[s] = f
        want.append(f)
    assert (got == np.array(want)).all()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 100)),
                min_size=1, max_size=30))
def test_lex_sort(items):
    p = jnp.asarray([x[0] for x in items], jnp.int32)
    s = jnp.asarray([x[1] for x in items], jnp.int32)
    t = jnp.arange(len(items), dtype=jnp.int32)
    valid = jnp.ones(len(items), bool)
    order = np.asarray(_lex_sort(p, s, t, valid))
    keys = [(items[i][0], items[i][1], i) for i in order]
    assert keys == sorted(keys)


def _mem_phase_at(t0: int):
    """One mem_phase call with contended L2 + DRAM traffic whose event
    times sit in [t0, t0+Δ).  Returns (req', mem', stats') — everything a
    time-shift-invariance check needs."""
    scfg, dyn = split_config(TINY)
    state = init_state(scfg)
    req, mem = state["req"], state["mem"]
    ns, m = req["stage"].shape

    stage = np.zeros((ns, m), np.int32)
    addr = np.zeros((ns, m), np.int32)
    t = np.zeros((ns, m), np.int32)
    # stage-1: six requests to ONE L2 slice (addr % l2_slices == 0) with
    # interleaved times + a tie — service order is everything here
    for i, (sm, row, a, dt) in enumerate([
            (0, 0, 4, 7), (1, 1, 8, 3), (2, 0, 12, 3),
            (3, 2, 16, 11), (5, 1, 20, 0), (7, 3, 24, 5)]):
        stage[sm, row], addr[sm, row], t[sm, row] = 1, a, t0 + dt
    # stage-2: six requests to ONE DRAM channel with clashing rows —
    # misordering flips the row-hit pattern and every finish time
    for sm, row, bank_row, dt in [(0, 4, 5, 2), (1, 5, 9, 6), (2, 4, 5, 1),
                                  (4, 4, 7, 9), (6, 4, 9, 4), (7, 5, 5, 13)]:
        stage[sm, row], addr[sm, row] = 2, 64 * bank_row
        t[sm, row] = t0 + dt
    req = dict(req, stage=jnp.asarray(stage), addr=jnp.asarray(addr),
               t=jnp.asarray(t))
    out_req, out_mem, out_stats = mem_phase(req, mem, state["stats"],
                                            jnp.int32(t0), scfg, dyn)
    return jax.tree_util.tree_map(np.asarray, (out_req, out_mem, out_stats))


def test_mem_phase_time_shift_invariance_past_int32_overflow():
    """Regression for the _lex_sort int32 overflow: with ABSOLUTE event
    time as the packed sort key, t ~ 2^25 × (r = n_sm·mshr rows) crosses
    2^31 and the service order silently scrambles.  Keying on
    quantum-relative time makes mem_phase exactly shift-equivariant: a
    run far past the old overflow point must replay the t0=0 run with
    every event time shifted by t0 and bit-identical stats."""
    t_big = (1 << 25) - 8          # keys straddle 2^31 under the old code
    req0, mem0, stats0 = _mem_phase_at(0)
    reqb, memb, statsb = _mem_phase_at(t_big)

    assert (reqb["stage"] == req0["stage"]).all()
    touched = req0["stage"] >= 2       # DRAM-bound misses + completed
    assert (req0["stage"] == 3).any() and (req0["stage"] == 2).any()
    assert (reqb["t"][touched] - req0["t"][touched] == t_big).all()
    for k in stats0:
        assert statsb[k] == stats0[k], k
    assert (memb["l2_tag"] == mem0["l2_tag"]).all()
    assert (memb["dram_row"] == mem0["dram_row"]).all()
