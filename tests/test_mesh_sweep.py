"""2-D ('cfg', 'sm') mesh distribution (core/distribute.py) — the
acceptance property: ``grid_sweep`` stats are bit-identical across mesh
shapes 1×1, 2×1, 1×2, 2×2 (and the no-mesh single-device path) on forced
host devices.  Subprocess because jax locks the host device count at
first init; shape-validation errors are cheap and run in-process."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    from repro.core import stats as S
    from repro.core.distribute import make_mesh
    from repro.core.sweep import grid_sweep
    from repro.sim.config import TINY
    from repro.sim.workloads import zoo_workload

    MAX = 1 << 14
    # lane 2 perturbs the per-class lat table, lane 3 the disp table, so
    # the (n_lanes, N_CLASSES) DynConfig table leaves are exercised under
    # P('cfg') sharding at every mesh shape
    cfgs = [TINY,
            dataclasses.replace(TINY, scheduler="lrr"),
            dataclasses.replace(TINY, l2_lat=64, dram_row_penalty=48,
                                lat_of_class=(24, 12, 48, 32, 0, 0, 1)),
            dataclasses.replace(TINY, l1_hit_lat=16, icnt_lat=24,
                                scheduler="lrr",
                                disp_of_class=(3, 2, 6, 4, 1, 1, 1))]
    ws = [zoo_workload(n, scale=0.02) for n in ("gemm_tiled", "mixed")]

    def sig(st):
        return dict(S.comparable(st), timeouts=st["timeouts"])

    results = {}
    for label, mesh in (("nomesh", None), ("1x1", make_mesh(1, 1)),
                        ("2x1", make_mesh(2, 1)), ("1x2", make_mesh(1, 2)),
                        ("2x2", make_mesh(2, 2))):
        g = grid_sweep(ws, cfgs, mesh=mesh, max_cycles=MAX)
        results[label] = [sig(g.stats[w][c])
                          for w in range(len(ws)) for c in range(len(cfgs))]
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_grid_sweep_mesh_shape_invariant():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    ref = results.pop("nomesh")
    assert any(s["cycles"] > 0 for s in ref)   # the sweep actually ran
    for shape, got in results.items():
        assert got == ref, f"mesh {shape} diverged from single-device run"


TRACE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    from repro.core import stats as S
    from repro.core.distribute import make_mesh
    from repro.core.sweep import grid_sweep
    from repro.sim.config import TINY
    from repro.sim.workloads import resolve_workload

    MAX = 1 << 14
    cfgs = [TINY,
            dataclasses.replace(TINY, scheduler="lrr"),
            dataclasses.replace(TINY, l2_lat=64, dram_row_penalty=48),
            dataclasses.replace(TINY, l1_hit_lat=16, icnt_lat=24)]
    # one real-trace workload (full ingest pipeline) next to a synthetic
    # one: trace-derived lanes must survive 'cfg'/'sm' sharding too
    ws = [resolve_workload("trace:gather_chain"),
          resolve_workload("mixed", scale=0.02)]

    def sig(st):
        return dict(S.comparable(st), timeouts=st["timeouts"])

    results = {}
    for label, mesh in (("nomesh", None), ("2x2", make_mesh(2, 2))):
        g = grid_sweep(ws, cfgs, mesh=mesh, max_cycles=MAX)
        results[label] = [sig(g.stats[w][c])
                          for w in range(len(ws)) for c in range(len(cfgs))]
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_trace_workload_grid_on_2x2_mesh():
    """Real-trace ingestion × distribution: a grid holding a
    trace-derived workload is bit-identical on a 2×2 ('cfg','sm') mesh
    vs the single-device run."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", TRACE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    ref = results.pop("nomesh")
    assert any(s["cycles"] > 0 for s in ref)
    assert results["2x2"] == ref


class _StubMesh:
    """check_mesh only reads axis_names/shape, so shape validation is
    testable without forcing multi-device jax state."""

    def __init__(self, n_cfg, n_sm, names=("cfg", "sm")):
        self.axis_names = names
        self.shape = {names[0]: n_cfg, names[-1]: n_sm}


def test_check_mesh_rejects_bad_shapes():
    from repro.core.distribute import check_mesh
    from repro.sim.config import TINY, static_part

    scfg = static_part(TINY)   # n_sm = 8
    check_mesh(_StubMesh(2, 2), scfg, n_lanes=4)          # divides: OK
    with pytest.raises(ValueError, match="lanes not divisible"):
        check_mesh(_StubMesh(3, 1), scfg, n_lanes=4)
    with pytest.raises(ValueError, match="n_sm=8 not divisible"):
        check_mesh(_StubMesh(1, 3), scfg, n_lanes=3)
    with pytest.raises(ValueError, match="axes"):
        check_mesh(_StubMesh(2, 2, names=("data", "model")), scfg, 4)


def test_make_mesh_too_few_devices():
    import jax

    from repro.core.distribute import make_mesh

    n = len(jax.devices())
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_mesh(n + 1, 1)
