"""PR 8 batching bet: RunPlan, bucketed lane packing, ragged layout,
early exit, compile caching.

The acceptance property stays the grid one — every bucketed/ragged lane
bit-identical to its solo run (the mixed zoo+trace version lives in
tests/test_zoo_grid.py, riding the solo-verified monolithic grid) — plus
the PR's own observables: bucketing is deterministic and order-preserving,
an entry-converged padding kernel charges ZERO quanta, a warm sweep skips
lower+compile entirely, and the legacy flat kwargs still work (warn once).
"""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import pytest

import repro.core.plan as plan_mod
from repro.core import batch
from repro.core import stats as S
from repro.core.batch import (INSTR_FIELDS, SCALAR_FIELDS, bucket_workloads,
                              concat_kernels, split_ragged, workload_cost,
                              workload_shape)
from repro.core.engine import run_kernel
from repro.core.parallel import make_sm_runner
from repro.core.plan import (RunPlan, enable_persistent_cache, resolve_plan)
from repro.core.sweep import clear_aot_cache, sweep
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.sim.workloads import zoo_workload

MAX_CYCLES = 1 << 13
SCALE = 0.005


# ---------------------------------------------------------------------------
# RunPlan validation + legacy shim
# ---------------------------------------------------------------------------

def test_runplan_rejects_bad_knobs():
    for kw in (dict(mode="shard"), dict(exchange="bogus"),
               dict(bucket_by="size"), dict(layout="flat"),
               dict(max_cycles=0), dict(max_buckets=0),
               dict(telemetry_samples=-1), dict(telemetry_every=0)):
        with pytest.raises(ValueError):
            RunPlan(**kw)


def test_runplan_mesh_needs_cfg_sm_axes():
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    with pytest.raises(ValueError, match=r"\('cfg','sm'\) mesh"):
        RunPlan(mesh=mesh)


def test_resolve_plan_rejects_mixed_plan_and_legacy():
    with pytest.raises(ValueError, match="not both"):
        resolve_plan(RunPlan(), where="sweep", max_cycles=64)


def test_resolve_plan_rejects_non_plan():
    with pytest.raises(TypeError, match="must be a RunPlan"):
        resolve_plan({"max_cycles": 64}, where="sweep")


def test_resolve_plan_tolerates_old_positional_mode():
    assert resolve_plan("seq", where="sweep").mode == "seq"
    with pytest.raises(ValueError, match="mode given twice"):
        resolve_plan("seq", where="sweep", mode="vmap")


def test_legacy_kwargs_build_plan_and_warn_once(monkeypatch):
    monkeypatch.setattr(plan_mod, "_warned_legacy", False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        p = resolve_plan(None, where="sweep", max_cycles=64, mode="seq")
        resolve_plan(None, where="sweep", max_cycles=64)
    assert (p.max_cycles, p.mode) == (64, "seq")
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "plan=RunPlan" in str(deps[0].message)


def test_runplan_describe_is_json_safe():
    json.dumps(RunPlan(bucket_by="cost", layout="ragged").describe())


# ---------------------------------------------------------------------------
# persistent compile cache wiring
# ---------------------------------------------------------------------------

def test_persistent_cache_idempotent_and_rewire_refused(tmp_path,
                                                        monkeypatch):
    monkeypatch.setattr(plan_mod, "_persistent_cache_dir", None)
    d = enable_persistent_cache(str(tmp_path / "cache"))
    if d is None:            # jax build without a compilation-cache config
        pytest.skip("no persistent compilation cache in this jax")
    assert enable_persistent_cache(str(tmp_path / "cache")) == d
    with pytest.raises(ValueError, match="refusing to re-wire"):
        enable_persistent_cache(str(tmp_path / "elsewhere"))


# ---------------------------------------------------------------------------
# ragged concat (cu_seqlens idiom)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixed_packs():
    w = zoo_workload("mixed", scale=SCALE)
    return [k.pack() for k in w.kernels]


def test_concat_kernels_offsets_and_shapes(mixed_packs):
    tr = concat_kernels(mixed_packs)
    lens = [int(p["n_instr"]) for p in mixed_packs]
    total = sum(lens)
    for f in INSTR_FIELDS:
        assert tr[f].shape[0] == total
    bases = [0]
    for n in lens[:-1]:
        bases.append(bases[-1] + n)
    assert [int(b) for b in tr["instr_base"]] == bases
    # the flat stream really is the kernels laid end to end
    for p, b in zip(mixed_packs, bases):
        assert jnp.array_equal(tr["ops"][b:b + int(p["n_instr"])], p["ops"])


def test_concat_kernels_padding_slots_are_inert(mixed_packs):
    k = len(mixed_packs)
    tr = concat_kernels(mixed_packs, n_kernels=k + 2)
    assert tr["n_ctas"].shape == (k + 2,)
    assert [int(v) for v in tr["n_ctas"][k:]] == [0, 0]
    # warps_per_cta pads with 1, never 0 — it divides in cta_issue
    assert [int(v) for v in tr["warps_per_cta"][k:]] == [1, 1]
    assert [int(v) for v in tr["instr_base"][k:]] == [0, 0]


def test_split_ragged_partition(mixed_packs):
    tr = concat_kernels(mixed_packs)
    scan_xs, flat = split_ragged(tr)
    assert set(scan_xs) == set(SCALAR_FIELDS) | {"instr_base"}
    assert set(flat) == set(INSTR_FIELDS)


# ---------------------------------------------------------------------------
# bucketing (pure host-side grouping)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def zoo_mix():
    return [zoo_workload(n, scale=SCALE)
            for n in ("gemm_tiled", "mixed", "reduction_tree",
                      "streaming_copy", "stencil")]


def test_bucket_none_is_single_identity_bucket(zoo_mix):
    groups = bucket_workloads(zoo_mix, by="none", max_buckets=4)
    assert groups == [list(range(len(zoo_mix)))]


def test_buckets_partition_and_respect_cap(zoo_mix):
    for by in ("shape", "cost"):
        for cap in (1, 2, 3, len(zoo_mix) + 3):
            groups = bucket_workloads(zoo_mix, by=by, max_buckets=cap)
            assert 1 <= len(groups) <= cap
            flat = sorted(i for g in groups for i in g)
            assert flat == list(range(len(zoo_mix)))
            # deterministic: same call, same grouping
            assert groups == bucket_workloads(zoo_mix, by=by,
                                              max_buckets=cap)


def test_shape_buckets_group_similar_lanes(zoo_mix):
    """Buckets split at the LARGEST shape gaps: every bucket's internal
    spread is no larger than the gap to the next bucket."""
    groups = bucket_workloads(zoo_mix, by="shape", max_buckets=3)
    keys = {i: workload_shape(w)[0] * workload_shape(w)[1]
            for i, w in enumerate(zoo_mix)}
    spans = [(min(keys[i] for i in g), max(keys[i] for i in g))
             for g in groups]
    spans.sort()
    for (lo_a, hi_a), (lo_b, _) in zip(spans, spans[1:]):
        assert hi_a <= lo_b      # buckets are contiguous key ranges


def test_cost_hint_overrides_instruction_count(zoo_mix):
    w = zoo_mix[0]
    default = workload_cost(w)
    assert default == sum(int(k.n_instr) * int(k.n_ctas)
                          for k in w.kernels)
    assert workload_cost(w, {w.name: 123.5}) == 123.5


def test_cost_hints_from_manifests(tmp_path):
    from repro.core.telemetry import COUNTERS
    wi = COUNTERS.index("lockstep_waste")
    tl = [[0.0] * len(COUNTERS), [0.0] * len(COUNTERS)]
    tl[-1][wi] = 40.0
    (tmp_path / "a.json").write_text(json.dumps({
        "stats": [{"workload": "mixed", "cycles": 100}],
        "timelines": {"mixed/0": tl}}))
    (tmp_path / "junk.json").write_text("{not json")
    hints = batch.cost_hints_from_manifests(str(tmp_path))
    assert hints["mixed"] == 140.0


# ---------------------------------------------------------------------------
# early exit: an entry-converged padding kernel charges ZERO quanta
# ---------------------------------------------------------------------------

def test_empty_kernel_runs_zero_quanta():
    scfg, dyn = split_config(TINY)
    w = zoo_workload("streaming_copy", scale=SCALE)
    tr = dict(w.kernels[0].pack())
    tr["n_ctas"] = jnp.zeros((), jnp.int32)   # a grid padding slot
    st = init_state(scfg)
    runner = make_sm_runner(scfg, "vmap")
    out = run_kernel(st, tr, scfg, dyn, runner, max_cycles=MAX_CYCLES,
                     early_exit=True)
    # zero while_loop iterations: the clock did not move, and done_cycle
    # was stamped at entry
    assert int(out["ctrl"]["cycle"]) == int(st["ctrl"]["cycle"])
    assert int(out["ctrl"]["done_cycle"]) == int(st["ctrl"]["cycle"])
    # without early exit the loop burns ≥1 full quantum discovering it
    out_slow = run_kernel(st, tr, scfg, dyn, runner, max_cycles=MAX_CYCLES,
                          early_exit=False)
    assert int(out_slow["ctrl"]["cycle"]) > int(st["ctrl"]["cycle"])


def test_real_kernel_never_entry_converged():
    scfg, dyn = split_config(TINY)
    w = zoo_workload("streaming_copy", scale=SCALE)
    from repro.core.engine import mark_entry_converged
    st = mark_entry_converged(init_state(scfg), w.kernels[0].pack())
    assert int(st["ctrl"]["done_cycle"]) == -1


# ---------------------------------------------------------------------------
# AOT executable cache: a warm sweep skips lower+compile
# ---------------------------------------------------------------------------

def test_sweep_aot_cache_warm_hit():
    clear_aot_cache()
    w = zoo_workload("streaming_copy", scale=SCALE)
    cfgs = [TINY, dataclasses.replace(TINY, scheduler="lrr")]
    plan = RunPlan(max_cycles=MAX_CYCLES)
    cold = sweep(w, cfgs, plan=plan)
    assert cold.timings["aot_cache"] == "miss"
    warm = sweep(w, cfgs, plan=plan)
    assert warm.timings["aot_cache"] == "hit"
    assert warm.timings["compile_s"] == 0.0
    for a, b in zip(cold.stats, warm.stats):
        assert S.comparable(a) == S.comparable(b)
    # a different plan knob is a different program: no false sharing
    other = sweep(w, cfgs, plan=RunPlan(max_cycles=MAX_CYCLES // 2))
    assert other.timings["aot_cache"] == "miss"
    clear_aot_cache()
