"""PR 8 batching bet: RunPlan, bucketed lane packing, ragged layout,
early exit, compile caching.

The acceptance property stays the grid one — every bucketed/ragged lane
bit-identical to its solo run (the mixed zoo+trace version lives in
tests/test_zoo_grid.py, riding the solo-verified monolithic grid) — plus
the PR's own observables: bucketing is deterministic and order-preserving,
an entry-converged padding kernel charges ZERO quanta, a warm sweep skips
lower+compile entirely, and the legacy flat kwargs still work (warn once).
"""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import pytest

import repro.core.plan as plan_mod
from repro.core import batch
from repro.core import stats as S
from repro.core.batch import (INSTR_FIELDS, SCALAR_FIELDS, bucket_workloads,
                              concat_kernels, split_ragged, workload_cost,
                              workload_shape)
from repro.core.engine import run_kernel
from repro.core.parallel import make_sm_runner
from repro.core.plan import (RunPlan, enable_persistent_cache, resolve_plan)
from repro.core.sweep import clear_aot_cache, sweep
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.sim.workloads import zoo_workload

MAX_CYCLES = 1 << 13
SCALE = 0.005


# ---------------------------------------------------------------------------
# RunPlan validation + legacy shim
# ---------------------------------------------------------------------------

def test_runplan_rejects_bad_knobs():
    for kw in (dict(mode="shard"), dict(exchange="bogus"),
               dict(bucket_by="size"), dict(layout="flat"),
               dict(max_cycles=0), dict(max_buckets=0),
               dict(telemetry_samples=-1), dict(telemetry_every=0)):
        with pytest.raises(ValueError):
            RunPlan(**kw)


def test_runplan_mesh_needs_cfg_sm_axes():
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    with pytest.raises(ValueError, match=r"\('cfg','sm'\) mesh"):
        RunPlan(mesh=mesh)


def test_resolve_plan_rejects_mixed_plan_and_legacy():
    with pytest.raises(ValueError, match="not both"):
        resolve_plan(RunPlan(), where="sweep", max_cycles=64)


def test_resolve_plan_rejects_non_plan():
    with pytest.raises(TypeError, match="must be a RunPlan"):
        resolve_plan({"max_cycles": 64}, where="sweep")


def test_resolve_plan_tolerates_old_positional_mode():
    assert resolve_plan("seq", where="sweep").mode == "seq"
    with pytest.raises(ValueError, match="mode given twice"):
        resolve_plan("seq", where="sweep", mode="vmap")


def test_legacy_kwargs_build_plan_and_warn_once(monkeypatch):
    monkeypatch.setattr(plan_mod, "_warned_legacy", False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        p = resolve_plan(None, where="sweep", max_cycles=64, mode="seq")
        resolve_plan(None, where="sweep", max_cycles=64)
    assert (p.max_cycles, p.mode) == (64, "seq")
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "plan=RunPlan" in str(deps[0].message)


def test_runplan_describe_is_json_safe():
    json.dumps(RunPlan(bucket_by="cost", layout="ragged").describe())


# ---------------------------------------------------------------------------
# persistent compile cache wiring
# ---------------------------------------------------------------------------

def test_persistent_cache_idempotent_and_rewire_refused(tmp_path,
                                                        monkeypatch):
    monkeypatch.setattr(plan_mod, "_persistent_cache_dir", None)
    d = enable_persistent_cache(str(tmp_path / "cache"))
    if d is None:            # jax build without a compilation-cache config
        pytest.skip("no persistent compilation cache in this jax")
    assert enable_persistent_cache(str(tmp_path / "cache")) == d
    with pytest.raises(ValueError, match="refusing to re-wire"):
        enable_persistent_cache(str(tmp_path / "elsewhere"))


# ---------------------------------------------------------------------------
# ragged concat (cu_seqlens idiom)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixed_packs():
    w = zoo_workload("mixed", scale=SCALE)
    return [k.pack() for k in w.kernels]


def test_concat_kernels_offsets_and_shapes(mixed_packs):
    tr = concat_kernels(mixed_packs)
    lens = [int(p["n_instr"]) for p in mixed_packs]
    total = sum(lens)
    for f in INSTR_FIELDS:
        assert tr[f].shape[0] == total
    bases = [0]
    for n in lens[:-1]:
        bases.append(bases[-1] + n)
    assert [int(b) for b in tr["instr_base"]] == bases
    # the flat stream really is the kernels laid end to end
    for p, b in zip(mixed_packs, bases):
        assert jnp.array_equal(tr["ops"][b:b + int(p["n_instr"])], p["ops"])


def test_concat_kernels_padding_slots_are_inert(mixed_packs):
    k = len(mixed_packs)
    tr = concat_kernels(mixed_packs, n_kernels=k + 2)
    assert tr["n_ctas"].shape == (k + 2,)
    assert [int(v) for v in tr["n_ctas"][k:]] == [0, 0]
    # warps_per_cta pads with 1, never 0 — it divides in cta_issue
    assert [int(v) for v in tr["warps_per_cta"][k:]] == [1, 1]
    assert [int(v) for v in tr["instr_base"][k:]] == [0, 0]


def test_split_ragged_partition(mixed_packs):
    tr = concat_kernels(mixed_packs)
    scan_xs, flat = split_ragged(tr)
    assert set(scan_xs) == set(SCALAR_FIELDS) | {"instr_base"}
    assert set(flat) == set(INSTR_FIELDS)


# ---------------------------------------------------------------------------
# bucketing (pure host-side grouping)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def zoo_mix():
    return [zoo_workload(n, scale=SCALE)
            for n in ("gemm_tiled", "mixed", "reduction_tree",
                      "streaming_copy", "stencil")]


def test_bucket_none_is_single_identity_bucket(zoo_mix):
    groups = bucket_workloads(zoo_mix, by="none", max_buckets=4)
    assert groups == [list(range(len(zoo_mix)))]


def test_buckets_partition_and_respect_cap(zoo_mix):
    for by in ("shape", "cost"):
        for cap in (1, 2, 3, len(zoo_mix) + 3):
            groups = bucket_workloads(zoo_mix, by=by, max_buckets=cap)
            assert 1 <= len(groups) <= cap
            flat = sorted(i for g in groups for i in g)
            assert flat == list(range(len(zoo_mix)))
            # deterministic: same call, same grouping
            assert groups == bucket_workloads(zoo_mix, by=by,
                                              max_buckets=cap)


def test_shape_buckets_group_similar_lanes(zoo_mix):
    """Buckets split at the LARGEST shape gaps: every bucket's internal
    spread is no larger than the gap to the next bucket."""
    groups = bucket_workloads(zoo_mix, by="shape", max_buckets=3)
    keys = {i: workload_shape(w)[0] * workload_shape(w)[1]
            for i, w in enumerate(zoo_mix)}
    spans = [(min(keys[i] for i in g), max(keys[i] for i in g))
             for g in groups]
    spans.sort()
    for (lo_a, hi_a), (lo_b, _) in zip(spans, spans[1:]):
        assert hi_a <= lo_b      # buckets are contiguous key ranges


def test_cost_hint_overrides_instruction_count(zoo_mix):
    w = zoo_mix[0]
    default = workload_cost(w)
    assert default == sum(int(k.n_instr) * int(k.n_ctas)
                          for k in w.kernels)
    assert workload_cost(w, {w.name: 123.5}) == 123.5


def test_cost_hints_from_manifests(tmp_path):
    from repro.core.telemetry import COUNTERS
    wi = COUNTERS.index("lockstep_waste")
    tl = [[0.0] * len(COUNTERS), [0.0] * len(COUNTERS)]
    tl[-1][wi] = 40.0
    (tmp_path / "a.json").write_text(json.dumps({
        "stats": [{"workload": "mixed", "cycles": 100}],
        "timelines": {"mixed/0": tl}}))
    (tmp_path / "junk.json").write_text("{not json")
    hints = batch.cost_hints_from_manifests(str(tmp_path))
    assert hints["mixed"] == 140.0


# ---------------------------------------------------------------------------
# early exit: an entry-converged padding kernel charges ZERO quanta
# ---------------------------------------------------------------------------

def test_empty_kernel_runs_zero_quanta():
    scfg, dyn = split_config(TINY)
    w = zoo_workload("streaming_copy", scale=SCALE)
    tr = dict(w.kernels[0].pack())
    tr["n_ctas"] = jnp.zeros((), jnp.int32)   # a grid padding slot
    st = init_state(scfg)
    runner = make_sm_runner(scfg, "vmap")
    out = run_kernel(st, tr, scfg, dyn, runner, max_cycles=MAX_CYCLES,
                     early_exit=True)
    # zero while_loop iterations: the clock did not move, and done_cycle
    # was stamped at entry
    assert int(out["ctrl"]["cycle"]) == int(st["ctrl"]["cycle"])
    assert int(out["ctrl"]["done_cycle"]) == int(st["ctrl"]["cycle"])
    # without early exit the loop burns ≥1 full quantum discovering it
    out_slow = run_kernel(st, tr, scfg, dyn, runner, max_cycles=MAX_CYCLES,
                          early_exit=False)
    assert int(out_slow["ctrl"]["cycle"]) > int(st["ctrl"]["cycle"])


def test_real_kernel_never_entry_converged():
    scfg, dyn = split_config(TINY)
    w = zoo_workload("streaming_copy", scale=SCALE)
    from repro.core.engine import mark_entry_converged
    st = mark_entry_converged(init_state(scfg), w.kernels[0].pack())
    assert int(st["ctrl"]["done_cycle"]) == -1


# ---------------------------------------------------------------------------
# AOT executable cache: a warm sweep skips lower+compile
# ---------------------------------------------------------------------------

def test_sweep_aot_cache_warm_hit():
    clear_aot_cache()
    w = zoo_workload("streaming_copy", scale=SCALE)
    cfgs = [TINY, dataclasses.replace(TINY, scheduler="lrr")]
    plan = RunPlan(max_cycles=MAX_CYCLES)
    cold = sweep(w, cfgs, plan=plan)
    assert cold.timings["aot_cache"] == "miss"
    warm = sweep(w, cfgs, plan=plan)
    assert warm.timings["aot_cache"] == "hit"
    assert warm.timings["compile_s"] == 0.0
    for a, b in zip(cold.stats, warm.stats):
        assert S.comparable(a) == S.comparable(b)
    # a different plan knob is a different program: no false sharing
    other = sweep(w, cfgs, plan=RunPlan(max_cycles=MAX_CYCLES // 2))
    assert other.timings["aot_cache"] == "miss"
    clear_aot_cache()


# ---------------------------------------------------------------------------
# property backfill (hypothesis): choose_bucket_count / gap partition /
# cost_hints_from_manifests — the pure host-side planning layer
# ---------------------------------------------------------------------------

from collections import namedtuple  # noqa: E402
import random  # noqa: E402
import tempfile  # noqa: E402

from _hyp import given, settings, st  # noqa: E402
from repro.core.batch import choose_bucket_count  # noqa: E402

# plain ints (shim-safe: no strategy chaining when hypothesis is absent);
# every consumer treats them as the float keys they stand for
_keys = st.lists(st.integers(min_value=1, max_value=10**6),
                 min_size=1, max_size=24)

FakeKernel = namedtuple("FakeKernel", "name n_instr n_ctas warps_per_cta")
FakeWorkload = namedtuple("FakeWorkload", "name kernels")


def _fake_workloads(keys):
    """One single-kernel workload per key: shape key = 1 * n_instr and
    cost key = n_instr * 1 both equal the raw key, so one generator
    drives both policies."""
    return [FakeWorkload(f"w{i}", [FakeKernel(f"k{i}", int(k), 1, 1)])
            for i, k in enumerate(keys)]


@settings(max_examples=50, deadline=None)
@given(_keys)
def test_choose_bucket_count_bounds_and_order_free(keys):
    """k ∈ [1, min(max_k, n)], and the choice depends only on the key
    MULTISET — lane order can never change how many programs compile."""
    k = choose_bucket_count(keys)
    assert 1 <= k <= min(8, len(keys))
    assert k == choose_bucket_count(sorted(keys))
    assert k == choose_bucket_count(sorted(keys, reverse=True))


@settings(max_examples=50, deadline=None)
@given(_keys, st.integers(min_value=2, max_value=100))
def test_choose_bucket_count_scale_invariant(keys, c):
    """Rescaling every key (and so the default mean-cost overhead) by a
    constant changes no trade-off: same bucket count."""
    assert choose_bucket_count(keys) == \
        choose_bucket_count([k * c for k in keys])


@settings(max_examples=50, deadline=None)
@given(_keys)
def test_choose_bucket_count_gap_monotone(keys):
    """Bucket count is monotone in gap structure at the extremes: a
    zero-gap key multiset never splits, and stretching the largest gap
    wide enough never REDUCES the count."""
    assert choose_bucket_count([keys[0]] * len(keys)) == 1
    if len(set(keys)) > 1:
        base = choose_bucket_count(keys)
        lo = sorted(keys)[:len(keys) // 2 + 1]
        stretched = lo + [k * 10**4 for k in sorted(keys)[len(lo):]]
        assert choose_bucket_count(stretched) >= min(base, 2)


@settings(max_examples=50, deadline=None)
@given(_keys, st.integers(min_value=1, max_value=9),
       st.sampled_from(["shape", "cost"]))
def test_bucket_partition_covers_every_lane_once(keys, cap, by):
    """For any key multiset, cap and policy: the groups PARTITION
    range(n) — every lane index appears exactly once, ≤ cap groups, and
    each group spans a contiguous key range.  (This partition property
    is what makes sweep reassembly order-preserving: grid_sweep and
    pair_sweep write ``stats[i]`` by original lane index, so as long as
    every index appears exactly once, hints and bucketing can never
    reorder or drop a lane's result.)"""
    ws = _fake_workloads(keys)
    groups = bucket_workloads(ws, by=by, max_buckets=cap)
    flat = [i for g in groups for i in g]
    assert sorted(flat) == list(range(len(ws)))
    assert 1 <= len(groups) <= cap
    spans = sorted((min(keys[i] for i in g), max(keys[i] for i in g))
                   for g in groups)
    for (_, hi_a), (lo_b, _) in zip(spans, spans[1:]):
        assert hi_a <= lo_b


@settings(max_examples=50, deadline=None)
@given(_keys, st.integers(min_value=1, max_value=9))
def test_cost_hints_change_grouping_never_membership(keys, cap):
    """Hints may regroup lanes but never add, drop or duplicate one —
    and hints agreeing with the default cost change nothing at all."""
    ws = _fake_workloads(keys)
    plain = bucket_workloads(ws, by="cost", max_buckets=cap)
    wild = bucket_workloads(ws, by="cost", max_buckets=cap,
                            cost_hints={w.name: 1.0 + (i % 3)
                                        for i, w in enumerate(ws)})
    for groups in (plain, wild):
        assert sorted(i for g in groups for i in g) == \
            list(range(len(ws)))
    agree = bucket_workloads(ws, by="cost", max_buckets=cap,
                             cost_hints={w.name: workload_cost(w)
                                         for w in ws})
    assert agree == plain


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(st.sampled_from(["gemm", "mixed", "stencil",
                                        "copy", "trace:x"]),
                       st.lists(st.integers(min_value=0,
                                            max_value=10**6),
                                min_size=1, max_size=4),
                       min_size=1, max_size=5),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_cost_hints_from_manifests_order_free(costs, seed):
    """Harvested hints are the per-workload MAX over all manifest
    entries — identical whatever order the entries are written in,
    across files or within one (dict/file-order shuffling)."""
    entries = [(name, c) for name, cs in costs.items() for c in cs]
    rng = random.Random(seed)
    harvests = []
    for _ in range(2):
        rng.shuffle(entries)
        cut = rng.randrange(len(entries) + 1)
        with tempfile.TemporaryDirectory() as d:
            for fname, chunk in (("a.json", entries[:cut]),
                                 ("b.json", entries[cut:])):
                with open(f"{d}/{fname}", "w") as f:
                    json.dump({"stats": [
                        {"workload": n, "cycles": c}
                        for n, c in chunk]}, f)
            harvests.append(batch.cost_hints_from_manifests(d))
    want = {n: float(max(cs)) for n, cs in costs.items()}
    assert harvests[0] == harvests[1] == want
