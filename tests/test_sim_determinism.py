"""The paper's headline property: parallel ≡ sequential, bit-exactly.

Property-based: random synthetic kernels must produce IDENTICAL stats under
the sequential (lax.map) and vectorized (vmap) SM runners.  The sharded
(multi-device) mode is covered by tests/test_sim_shard.py (subprocess).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import TINY, BAR, FP32, INT32, LDG, SFU, STG, TENSOR
from repro.sim.trace import (A_RANDOM, A_STREAM, A_STRIDED, KernelTrace,
                             Workload)
from repro.workloads import arch_workload, make_workload


def run(workload, mode):
    st_ = simulate(workload, TINY, make_sm_runner(TINY, mode),
                   max_cycles=1 << 15)
    return S.comparable(S.finalize(st_))


def test_myocyte_two_ctas():
    out = run(make_workload("myocyte", scale=1.0), "vmap")
    assert out["ctas_launched"] == 2          # paper's Fig. 7 pathology
    # only 2 SMs can ever be busy
    st_ = simulate(make_workload("myocyte", scale=1.0), TINY,
                   make_sm_runner(TINY, "vmap"), max_cycles=1 << 15)
    busy = np.asarray(st_["stats_sm"]["issued"]) > 0
    assert busy.sum() <= 2


@pytest.mark.parametrize("bench", ["hotspot", "sssp", "cut_1"])
def test_seq_equals_vmap(bench):
    w = make_workload(bench, scale=0.02)
    assert run(w, "seq") == run(w, "vmap")


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_property_random_kernels(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 1 << 16)))
    n_instr = int(rng.integers(4, 24))
    ops = rng.choice([FP32, INT32, SFU, TENSOR, LDG, STG, BAR],
                     size=n_instr).astype(np.int32)
    trace = KernelTrace(
        name="rand", n_ctas=int(rng.integers(1, 24)),
        warps_per_cta=int(rng.integers(1, 4)),
        ops=ops, dep=rng.random(n_instr) < 0.5,
        addr_mode=rng.choice([A_STREAM, A_STRIDED, A_RANDOM],
                             size=n_instr).astype(np.int32),
        addr_param=rng.integers(0, 64, n_instr).astype(np.int32))
    w = Workload("rand", [trace])
    a, b = run(w, "seq"), run(w, "vmap")
    assert a == b
    assert a["ctas_launched"] == trace.n_ctas
    assert a["issued"] >= trace.n_ctas * trace.warps_per_cta  # all ran


def test_lm_workload_runs():
    from repro.configs import SHAPES, get_config
    w = arch_workload(get_config("qwen2-72b"), SHAPES["train_4k"],
                      token_div=4096)
    out = run(w, "vmap")
    assert out["issued"] > 0 and out["cycles"] > 0


def test_l1_and_l2_hits_occur():
    """Workloads that revisit addresses must produce cache hits somewhere
    (myocyte repeats its per-warp stream 24×)."""
    out = run(make_workload("myocyte", scale=1.0), "vmap")
    assert out["l1_hit"] + out["l2_hit"] > 0
    assert out["dram_req"] > 0


def test_unique_addr_stat():
    out = run(make_workload("nn", scale=0.05), "vmap")
    assert 0 < out["unique_addrs"]
