"""Zoo + grid-sweep frontend: the acceptance property.

A ``grid_sweep`` over ≥4 zoo workloads × ≥4 configs runs as ONE jitted
program; every (workload, config) lane — including lanes whose workload
was padded with NOP slots / empty kernels to reach the shared shape —
must be bit-identical to a solo ``simulate()`` of that pair, cycles,
stats and timeout accounting alike.
"""
import dataclasses

import pytest

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.core.sweep import grid_sweep
from repro.sim.config import TINY
from repro.sim.workloads import ZOO, zoo_names, zoo_workload

MAX_CYCLES = 1 << 15
SCALE = 0.02

# 4 workloads with deliberately different kernel counts and lengths, so
# at least three of them are padded on both axes in the stacked batch
GRID_WORKLOADS = ("gemm_tiled", "mixed", "reduction_tree", "streaming_copy")
GRID_CFGS = [
    TINY,
    dataclasses.replace(TINY, scheduler="lrr"),
    dataclasses.replace(TINY, l2_lat=64, dram_row_penalty=48),
    dataclasses.replace(TINY, l1_hit_lat=16, icnt_lat=24, scheduler="lrr"),
]


def signature(stats):
    return dict(S.comparable(stats), timeouts=stats["timeouts"])


@pytest.fixture(scope="module")
def grid():
    ws = [zoo_workload(n, scale=SCALE) for n in GRID_WORKLOADS]
    return ws, grid_sweep(ws, GRID_CFGS, max_cycles=MAX_CYCLES)


@pytest.mark.parametrize("w", range(len(GRID_WORKLOADS)))
@pytest.mark.parametrize("c", range(len(GRID_CFGS)))
def test_grid_lane_equals_solo(grid, w, c):
    ws, result = grid
    cfg = GRID_CFGS[c]
    solo = signature(S.finalize(simulate(
        ws[w], cfg, make_sm_runner(cfg, "vmap"), max_cycles=MAX_CYCLES)))
    assert signature(result.stats[w][c]) == solo


def test_grid_lanes_are_distinct(grid):
    """The grid really sweeps: no two workload rows collapse to one
    result, and config columns differ within a row."""
    _, result = grid
    rows = [S.comparable(result.stats[w][0])
            for w in range(len(GRID_WORKLOADS))]
    assert len({tuple(sorted(r.items())) for r in rows}) == len(rows)
    first = [S.comparable(result.stats[0][c]) for c in range(len(GRID_CFGS))]
    assert len({tuple(sorted(r.items())) for r in first}) > 1


def test_zoo_registry_complete():
    """The zoo holds the advertised ~8 distinct workloads and every entry
    builds a non-empty workload whose name matches its key."""
    assert len(ZOO) >= 8
    expected = {"gemm_tiled", "stencil", "streaming_copy",
                "strided_transpose", "random_gather", "reduction_tree",
                "tensor_heavy", "mixed"}
    assert expected <= set(zoo_names())
    for name in zoo_names():
        w = zoo_workload(name, scale=0.02)
        assert w.kernels, name
        assert w.name == name
        assert all(k.n_ctas >= 1 for k in w.kernels), name


def test_zoo_unknown_name():
    with pytest.raises(KeyError, match="unknown zoo workload"):
        zoo_workload("nope")


# ---------------------------------------------------------------------------
# mixed trace + synthetic grid (real-trace ingestion, sim/traceio.py)
# ---------------------------------------------------------------------------

MIXED_CFGS = GRID_CFGS[:2]


@pytest.fixture(scope="module")
def mixed_grid():
    """2 trace-derived + 2 synthetic workloads in ONE stacked grid.  The
    trace kernels differ from the zoo's in kernel count, length, CTA
    count and warps_per_cta, so both padding axes are exercised with
    real-trace rows in the batch."""
    from repro.sim.workloads import resolve_workload

    names = ("trace:vecadd", "trace:gather_chain", "random_gather",
             "stencil")
    ws = [resolve_workload(n, scale=1.0 if n.startswith("trace:") else SCALE)
          for n in names]
    return ws, grid_sweep(ws, MIXED_CFGS, max_cycles=MAX_CYCLES)


@pytest.mark.parametrize("w", range(4))
@pytest.mark.parametrize("c", range(len(MIXED_CFGS)))
def test_mixed_trace_grid_lane_equals_solo(mixed_grid, w, c):
    ws, result = mixed_grid
    cfg = MIXED_CFGS[c]
    solo = signature(S.finalize(simulate(
        ws[w], cfg, make_sm_runner(cfg, "vmap"), max_cycles=MAX_CYCLES)))
    assert signature(result.stats[w][c]) == solo


def test_mixed_trace_grid_rows_distinct(mixed_grid):
    _, result = mixed_grid
    rows = [S.comparable(result.stats[w][0]) for w in range(4)]
    assert len({tuple(sorted(r.items())) for r in rows}) == len(rows)


# ---------------------------------------------------------------------------
# bucketed + ragged packing (PR 8): same grid, per-bucket programs
# ---------------------------------------------------------------------------

def test_bucketed_ragged_mixed_grid_bit_exact(mixed_grid):
    """The same mixed zoo+trace grid run bucketed-by-shape with the
    ragged (instr_base-offset) trace layout: every lane must match the
    monolithic padded grid — whose lanes the tests above pin bit-exact to
    solo runs — so bucketing/raggedness change only the packing, never a
    single counter.  Also pins the reassembly bookkeeping: stats come
    back in the original lane order and lane_state() finds each lane in
    whichever bucket ran it."""
    from repro.core.plan import RunPlan

    ws, mono = mixed_grid
    plan = RunPlan(max_cycles=MAX_CYCLES, bucket_by="shape",
                   max_buckets=3, layout="ragged")
    bucketed = grid_sweep(ws, MIXED_CFGS, plan=plan)
    assert bucketed.timings["n_buckets"] > 1    # the grid really split
    for w in range(len(ws)):
        for c in range(len(MIXED_CFGS)):
            assert signature(bucketed.stats[w][c]) == \
                signature(mono.stats[w][c]), (ws[w].name, c)
    # lane_state reaches into the right bucket for every lane
    for w in range(len(ws)):
        st = bucketed.lane_state(w, 0)
        assert int(st["ctrl"]["cycle"]) >= 0
