"""Layer-level unit + property tests (rope, loss, moe, mamba, rwkv)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.models.layers.rope import apply_rope
from repro.models.loss import chunked_cross_entropy


def test_rope_norm_preserving():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 4, 32))
    pos = jnp.arange(8)[None].repeat(2, 0)
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative():
    """q·k after rope depends only on relative distance."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))

    def score(pq, pk):
        qr = apply_rope(q, jnp.full((1, 1), pq), theta=100.0)
        kr = apply_rope(k, jnp.full((1, 1), pk), theta=100.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(9, 7)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(4, 40), st.integers(5, 40))
def test_chunked_ce_matches_direct(b, s, v):
    key = jax.random.PRNGKey(s * 100 + v)
    hidden = jax.random.normal(key, (b, s, 8))
    w = jax.random.normal(jax.random.PRNGKey(0), (8, v))
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, v)
    got = chunked_cross_entropy(hidden, w, labels, chunk=4)
    logits = hidden @ w
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(got, want, rtol=2e-5)


def test_moe_top1_equals_dense_expert():
    """With top-1 routing and no drops, each token goes through exactly its
    argmax expert."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.models.layers.moe import apply_moe, init_moe

    cfg = get_reduced("arctic-480b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, top_k=1, capacity_factor=8.0, dense_residual=False))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, _ = apply_moe(p, x, cfg=cfg)
    # manual per-token expert apply
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    eidx = jnp.argmax(logits, -1)
    gate = jnp.einsum("bsd,edf->bsef", x, p["wi_gate"])
    up = jnp.einsum("bsd,edf->bsef", x, p["wi_up"])
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    want = jnp.take_along_axis(
        out, eidx[..., None, None].repeat(cfg.d_model, -1), axis=2)[:, :, 0]
    np.testing.assert_allclose(y, want, atol=2e-5)


def test_mamba_chunked_equals_stepwise():
    from repro.models.layers.mamba import ssm_chunked

    key = jax.random.PRNGKey(0)
    b, s, di, ds = 2, 32, 8, 4
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di)))
    a = -jnp.exp(jax.random.normal(ks[1], (di, ds)))
    bmat = jax.random.normal(ks[2], (b, s, ds))
    cmat = jax.random.normal(ks[3], (b, s, ds))
    u = jax.random.normal(ks[4], (b, s, di))
    h0 = jnp.zeros((b, di, ds))
    y, h = ssm_chunked(dt, a, bmat, cmat, u, h0, chunk=8)

    # literal recurrence
    def step(hh, i):
        da = jnp.exp(dt[:, i, :, None] * a)
        hh = da * hh + (dt[:, i] * u[:, i])[..., None] * bmat[:, i, None, :]
        return hh, jnp.einsum("bds,bs->bd", hh, cmat[:, i])

    hN, ys = jax.lax.scan(step, h0, jnp.arange(s))
    ys = jnp.moveaxis(ys, 0, 1)
    np.testing.assert_allclose(y, ys, rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(h, hN, rtol=2e-4, atol=1e-4)


def test_rwkv_chunked_equals_stepwise():
    from repro.kernels.wkv6.ref import wkv_ref_chunked, wkv_ref_stepwise

    key = jax.random.PRNGKey(0)
    b, s, h, hs = 2, 48, 2, 16
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hs)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, hs)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, hs)) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hs)) - 1)
    u = 0.3 * jax.random.normal(ks[4], (h, hs))
    s0 = jnp.zeros((b, h, hs, hs))
    o1, st1 = wkv_ref_stepwise(r, k, v, w, u, s0)
    o2, st2 = wkv_ref_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(st1, st2, rtol=1e-4, atol=1e-5)
