"""DSE layer: every vmap lane of a batched config sweep must equal a solo
engine run of that config bit-exactly — including lanes where only the
scheduler selector differs (GTO vs LRR share one compiled program) and
lanes whose per-class ``lat``/``disp`` timing tables are perturbed (the
typed DynConfig's table leaves are traced, per-lane values)."""
import dataclasses

import pytest

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.core.sweep import stack_dyn, sweep
from repro.sim.config import SCHED_GTO, SCHED_LRR, TINY, split_config
from repro.workloads import make_workload

MAX_CYCLES = 1 << 15

# lanes 0/1 differ ONLY in the scheduler selector; lanes 2/3 vary scalar
# timing knobs; lanes 4/5 perturb the per-class lat/disp TABLES
SWEEP_CFGS = [
    dataclasses.replace(TINY, scheduler="gto"),
    dataclasses.replace(TINY, scheduler="lrr"),
    dataclasses.replace(TINY, l2_lat=64, dram_row_penalty=48),
    dataclasses.replace(TINY, l1_hit_lat=16, icnt_lat=24, scheduler="lrr"),
    dataclasses.replace(TINY, lat_of_class=(24, 12, 48, 32, 0, 0, 1)),
    dataclasses.replace(TINY, disp_of_class=(3, 2, 6, 4, 1, 1, 1),
                        scheduler="lrr"),
]


def solo(workload, cfg):
    return S.comparable(S.finalize(simulate(
        workload, cfg, make_sm_runner(cfg, "vmap"), max_cycles=MAX_CYCLES)))


@pytest.fixture(scope="module")
def batched():
    w = make_workload("hotspot", scale=0.01)
    return w, sweep(w, SWEEP_CFGS, max_cycles=MAX_CYCLES)


@pytest.mark.parametrize("i", range(len(SWEEP_CFGS)))
def test_lane_equals_solo(batched, i):
    w, result = batched
    assert S.comparable(result.stats[i]) == solo(w, SWEEP_CFGS[i])


def test_scheduler_lanes_differ(batched):
    """GTO and LRR lanes share one program but must not collapse to one
    result (the selector really is traced, not baked in)."""
    _, result = batched
    sched = [split_config(c)[1].core.sched for c in SWEEP_CFGS[:2]]
    assert (int(sched[0]), int(sched[1])) == (SCHED_GTO, SCHED_LRR)
    assert S.comparable(result.stats[0]) != S.comparable(result.stats[1])


def test_table_lanes_differ_from_default(batched):
    """A perturbed dispatch-table lane must not collapse onto the
    default-table lane with the same scheduler — the tables really are
    traced per-lane leaves, not baked-in constants.  (hotspot is
    result-latency-insensitive — loads dominate its dependence chains —
    so the lat-table distinctness check lives in test_dyn_config.py on a
    compute-bound zoo workload; here lane 4 is still proven bit-exact
    against its solo run by test_lane_equals_solo.)"""
    _, result = batched
    assert S.comparable(result.stats[5]) != S.comparable(result.stats[1])


def test_stack_dyn_rejects_shape_mismatch():
    other = dataclasses.replace(TINY, n_sm=4)
    with pytest.raises(ValueError, match="static shape"):
        stack_dyn([TINY, other])


def test_stack_dyn_rejects_empty():
    with pytest.raises(ValueError):
        stack_dyn([])
