"""Config registry: published dims, param counts, cell applicability."""
import pytest

from repro.configs import SHAPES, get_config, get_reduced, list_archs

PUBLISHED_B = {
    "codeqwen1.5-7b": 7.25, "qwen2-72b": 72.7, "phi3-medium-14b": 14.0,
    "minitron-8b": 8.0, "rwkv6-1.6b": 1.6, "qwen2-vl-2b": 1.5,
    "jamba-v0.1-52b": 52.0, "arctic-480b": 480.0,
    "deepseek-v3-671b": 671.0, "whisper-base": 0.074,
}


def test_ten_archs():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list(PUBLISHED_B))
def test_param_count_near_published(arch):
    n = get_config(arch).param_count() / 1e9
    pub = PUBLISHED_B[arch]
    assert abs(n - pub) / pub < 0.35, (arch, n, pub)


def test_cells_total_40():
    total = sum(len(get_config(a).cells()) + len(get_config(a).skipped_cells())
                for a in list_archs())
    assert total == 40


def test_long_context_only_subquadratic():
    for a in list_archs():
        cfg = get_config(a)
        runs_long = any(s.name == "long_500k" for s in cfg.cells())
        assert runs_long == (cfg.family in ("ssm", "hybrid"))


@pytest.mark.parametrize("arch", list(PUBLISHED_B))
def test_reduced_configs_small(arch):
    r = get_reduced(arch)
    assert r.param_count() < 50e6
    assert r.resolved_head_dim % 8 == 0  # rope block alignment


def test_shapes():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["decode_32k"].tokens == 128
    assert SHAPES["long_500k"].is_decode
