"""Telemetry invariants (core/telemetry.py).

The contract the observability layer must keep:

1. OFF is free: with ``telemetry_samples == 0`` (the default) the state
   pytree is unchanged — no ``telem`` part, so the compiled program and
   the committed determinism golden are untouched.
2. ON is invisible to timing: enabling telemetry leaves the
   ``comparable()`` stat subset bit-identical to the telemetry-off run
   (the golden), in every execution mode.
3. The last timeline sample IS the final state: for every lane, the
   forced end-of-kernel sample equals ``stats.finalize`` totals on every
   cumulative counter — across seq, vmap, grid-vmap and (subprocess,
   @slow) the 2-D ('cfg','sm') mesh.

Plus serialization (stats.to_jsonable) and manifest/report-CLI smoke.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import stats as S
from repro.core import telemetry as T
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.core.sweep import grid_sweep, sweep, take_grid_lane, take_lane
from repro.sim.config import TINY, split_config, static_part
from repro.sim.state import init_state
from repro.sim.workloads import zoo_workload

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MAX = 1 << 14
TELEM = dataclasses.replace(TINY, telemetry_samples=32, telemetry_every=2)


def tiny_workload(scale=0.02):
    return zoo_workload("mixed", scale=scale)


# ---------------------------------------------------------------------------
# 1. off is free
# ---------------------------------------------------------------------------

def test_off_state_pytree_unchanged():
    assert not T.enabled(static_part(TINY))
    assert "telem" not in init_state(TINY)
    # and the finalize output grows no telemetry keys either
    st = simulate(tiny_workload(), TINY, make_sm_runner(TINY, "vmap"),
                  max_cycles=MAX)
    out = S.finalize(st)
    assert "lockstep_waste" not in out
    assert "telemetry_samples" not in out


def test_on_state_has_telem_part():
    scfg = static_part(TELEM)
    assert T.enabled(scfg)
    st = init_state(TELEM)
    assert st["telem"]["buf"].shape == (32, T.N_COUNTERS)


# ---------------------------------------------------------------------------
# 2. on is invisible to timing (matches the committed golden)
# ---------------------------------------------------------------------------

def test_on_matches_determinism_golden():
    """Telemetry-on hotspot@0.02 must reproduce the committed golden's
    comparable() stats bit-exactly — sampling must not perturb timing."""
    from repro.workloads import make_workload
    golden_path = os.path.join(REPO, "tests", "golden",
                               "determinism_tiny.json")
    with open(golden_path) as f:
        golden = json.load(f)["hotspot@0.02"]
    w = make_workload("hotspot", scale=0.02)
    st = simulate(w, TELEM, make_sm_runner(TELEM, "vmap"),
                  max_cycles=1 << 15)
    assert S.comparable(S.finalize(st)) == golden


# ---------------------------------------------------------------------------
# 3. last sample == finalize totals, every mode / every lane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["seq", "vmap"])
def test_final_sample_matches_finalize(mode):
    w = tiny_workload()
    st = simulate(w, TELEM, make_sm_runner(TELEM, mode), max_cycles=MAX)
    out = S.finalize(st)
    assert out["telemetry_samples"] > 0
    assert T.check_final_sample(st, out) == []
    # the cycle column is monotonically nondecreasing
    tl = T.timeline(st)
    cyc = tl[:, T.COUNTERS.index("cycle")]
    assert (np.diff(cyc) >= 0).all()


def test_seq_vmap_timelines_identical():
    w = tiny_workload()
    tls = {}
    for mode in ("seq", "vmap"):
        st = simulate(w, TELEM, make_sm_runner(TELEM, mode), max_cycles=MAX)
        tls[mode] = T.timeline(st)
    assert np.array_equal(tls["seq"], tls["vmap"])


def test_sweep_lanes_final_samples():
    """Vmapped config sweep: every lane carries its own timeline whose
    last row equals that lane's finalize totals."""
    cfgs = [TELEM, dataclasses.replace(TELEM, scheduler="lrr"),
            dataclasses.replace(TELEM, l2_lat=64)]
    res = sweep(tiny_workload(), cfgs, max_cycles=MAX)
    tls = res.timelines()
    assert set(tls) == {"0", "1", "2"}
    for i in range(len(cfgs)):
        lane = take_lane(res.state, i)
        assert T.check_final_sample(lane, res.stats[i]) == [], i
    # lanes with different configs produced different timelines
    assert not np.array_equal(tls["0"], tls["2"])


def test_grid_sweep_lanes_final_samples():
    ws = [zoo_workload("gemm_tiled", scale=0.02), tiny_workload()]
    cfgs = [TELEM, dataclasses.replace(TELEM, scheduler="lrr")]
    res = grid_sweep(ws, cfgs, max_cycles=MAX)
    assert set(res.timelines()) == {"gemm_tiled/0", "gemm_tiled/1",
                                    "mixed/0", "mixed/1"}
    for w in range(2):
        for c in range(2):
            lane = take_grid_lane(res.state, w, c)
            assert T.check_final_sample(lane, res.stats[w][c]) == [], (w, c)


def test_sweep_comparable_off_vs_on():
    """The same sweep with telemetry off/on: comparable() bit-identical,
    and timings report the compile/execute split."""
    cfgs_off = [TINY, dataclasses.replace(TINY, scheduler="lrr")]
    cfgs_on = [dataclasses.replace(c, telemetry_samples=16)
               for c in cfgs_off]
    w = tiny_workload()
    off = sweep(w, cfgs_off, max_cycles=MAX)
    on = sweep(w, cfgs_on, max_cycles=MAX)
    for i in range(2):
        assert S.comparable(off.stats[i]) == S.comparable(on.stats[i])
    for res in (off, on):
        assert res.timings["n_lanes"] == 2
        assert res.timings["execute_s"] > 0
    assert off.timelines() == {}


MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import numpy as np
    from repro.core import stats as S
    from repro.core import telemetry as T
    from repro.core.distribute import make_mesh
    from repro.core.sweep import grid_sweep, take_grid_lane
    from repro.sim.config import TINY
    from repro.sim.workloads import zoo_workload

    MAX = 1 << 14
    TELEM = dataclasses.replace(TINY, telemetry_samples=32,
                                telemetry_every=2)
    cfgs = [TELEM, dataclasses.replace(TELEM, scheduler="lrr")]
    ws = [zoo_workload(n, scale=0.02) for n in ("gemm_tiled", "mixed")]

    out = {}
    for label, mesh in (("nomesh", None), ("2x2", make_mesh(2, 2))):
        g = grid_sweep(ws, cfgs, mesh=mesh, max_cycles=MAX)
        bad = []
        for w in range(2):
            for c in range(2):
                lane = take_grid_lane(g.state, w, c)
                bad += [f"{w}/{c}:{n}" for n in
                        T.check_final_sample(lane, g.stats[w][c])]
        out[label] = {
            "bad": bad,
            "comparable": [S.comparable(g.stats[w][c])
                           for w in range(2) for c in range(2)],
            "timelines": {k: v.tolist()
                          for k, v in g.timelines().items()},
        }
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_mesh_final_samples_and_timelines_match_single_device():
    """2-D ('cfg','sm') mesh: per-lane final samples still equal finalize
    totals (psum over 'sm' sees the whole machine), and the full sampled
    timelines are bit-identical to the single-device run."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["nomesh"]["bad"] == []
    assert res["2x2"]["bad"] == []
    assert res["2x2"]["comparable"] == res["nomesh"]["comparable"]
    assert res["2x2"]["timelines"] == res["nomesh"]["timelines"]


# ---------------------------------------------------------------------------
# serialization + manifest/report smoke
# ---------------------------------------------------------------------------

def test_to_jsonable_roundtrip():
    import jax.numpy as jnp
    payload = {
        "a": np.int64(3), "b": np.arange(3), "c": (1, np.float32(2.5)),
        "d": {"nested": jnp.zeros((), jnp.int32)},
        "e": True, "f": np.bool_(False), "g": None, "h": "s",
    }
    out = json.loads(json.dumps(S.to_jsonable(payload)))
    assert out == {"a": 3, "b": [0, 1, 2], "c": [1, 2.5],
                   "d": {"nested": 0}, "e": True, "f": False,
                   "g": None, "h": "s"}
    # bools must stay bools (bool is an int subclass)
    assert out["e"] is True and out["f"] is False
    # full finalize output serializes (the *_per_sm int64 arrays)
    st = simulate(tiny_workload(), TINY, make_sm_runner(TINY, "vmap"),
                  max_cycles=MAX)
    json.dumps(S.to_jsonable(S.finalize(st)))


def test_manifest_write_and_report(tmp_path, capsys):
    from repro.launch.report import diff_stats, render_timeline
    cfgs = [TELEM, dataclasses.replace(TELEM, scheduler="lrr")]
    res = sweep(tiny_workload(), cfgs, max_cycles=MAX)
    path = T.write_manifest(
        "testrun", scfg=res.scfg, timings=res.timings, stats=res.stats,
        timelines={k: v.tolist() for k, v in res.timelines().items()},
        lanes=[{"scheduler": c.scheduler} for c in cfgs],
        out_dir=str(tmp_path))
    with open(path) as f:
        m = json.load(f)
    assert m["schema"] == T.MANIFEST_SCHEMA
    assert m["kind"] == "testrun"
    assert m["static_config_hash"] == T.static_hash(res.scfg)
    assert m["telemetry"]["counters"] == list(T.COUNTERS)
    assert {"hostname", "device_count"} <= set(m["host"])
    assert len(m["timelines"]) == 2 and len(m["stats"]) == 2
    # report: the timeline renderer verifies last-sample == finalize and
    # returns the mismatch count — 0 on a real manifest
    assert render_timeline(m) == 0
    txt = capsys.readouterr()  # sparkline output went to stdout
    # diff against itself: no comparable() differences
    assert diff_stats(m, m) == []
    del txt


def test_manifest_no_same_second_overwrite(tmp_path):
    a = T.write_manifest("x", out_dir=str(tmp_path))
    b = T.write_manifest("x", out_dir=str(tmp_path))
    assert a != b and os.path.exists(a) and os.path.exists(b)


def test_static_hash_stable_and_distinct():
    scfg = static_part(TINY)
    assert T.static_hash(scfg) == T.static_hash(static_part(TINY))
    assert T.static_hash(scfg) != T.static_hash(static_part(TELEM))


def test_launcher_flags_smoke():
    """dse --telemetry writes a manifest whose timelines verify (the
    acceptance-criteria path, minus the subprocess)."""
    from repro.launch import dse
    runs_before = set(os.listdir(T.runs_dir())) \
        if os.path.isdir(T.runs_dir()) else set()
    dse.main(["--n", "2", "--scale", "0.005", "--telemetry", "8",
              "--telemetry-every", "4", "--max-cycles", str(MAX)])
    new = [f for f in os.listdir(T.runs_dir())
           if f not in runs_before and f.endswith(".json")]
    assert new, "dse wrote no manifest"
    from repro.launch.report import render_timeline
    newest = max(new)
    with open(os.path.join(T.runs_dir(), newest)) as f:
        m = json.load(f)
    try:
        assert m["kind"] == "dse"
        assert render_timeline(m, out=open(os.devnull, "w")) == 0
    finally:
        for f in new:  # keep the repo's experiments/runs clean under test
            os.unlink(os.path.join(T.runs_dir(), f))
