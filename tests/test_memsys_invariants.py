"""Memory-system invariants, property-tested two ways: seeded random
request tables straight into ``mem_phase`` (always run), plus a
hypothesis-driven variant when the package is installed (_hyp shim).

Invariants:
  · request stages only advance inside the memory phase (0/3 untouched,
    1 → {2,3}, 2 → 3) and response times strictly increase on advance;
  · in-flight MSHR rows per SM never exceed ``mshr_per_sm`` and non-store
    in-flight rows account exactly for the warps' pending-load counters;
  · the machine clock is strictly monotone: +Δ per quantum, busy_until
    recurrences never rewind, done_cycle latches once.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core.engine import quantum_step
from repro.core.parallel import make_sm_runner
from repro.sim.config import TINY, split_config
from repro.sim.memsys import mem_phase
from repro.sim.state import init_state
from repro.workloads import make_workload

SCFG, DYN = split_config(TINY)


def random_mem_inputs(rng, t0=64):
    ns, m = SCFG.n_sm, SCFG.mshr_per_sm
    state = init_state(SCFG)
    req = {
        "stage": jnp.asarray(rng.integers(0, 4, (ns, m)), jnp.int32),
        "addr": jnp.asarray(rng.integers(0, 4096, (ns, m)), jnp.int32),
        "t": jnp.asarray(rng.integers(0, t0 + 2 * SCFG.quantum, (ns, m)),
                         jnp.int32),
        "warp": jnp.zeros((ns, m), jnp.int32),
        "is_store": jnp.asarray(rng.integers(0, 2, (ns, m)) == 1),
    }
    return req, state["mem"], state["stats"]


def check_mem_phase_invariants(req, mem, stats, t0):
    req2, mem2, _ = mem_phase(req, mem, stats, t0, SCFG, DYN)
    s0 = np.asarray(req["stage"])
    s1 = np.asarray(req2["stage"])
    t_before = np.asarray(req["t"])
    t_after = np.asarray(req2["t"])
    assert ((s1 >= 0) & (s1 <= 3)).all()
    # stages only advance; free (0) and done (3) rows are never touched
    assert (s1 >= s0).all(), "mem_phase moved a request backwards"
    assert (s1[s0 == 0] == 0).all() and (s1[s0 == 3] == 3).all()
    adv = s1 > s0
    assert (t_after[adv] > t_before[adv]).all(), \
        "advancing a request must move its event time forward"
    assert (t_after[~adv] == t_before[~adv]).all()
    # queue recurrences never rewind
    for k in ("l2_busy", "dram_busy"):
        assert (np.asarray(mem2[k]) >= np.asarray(mem[k])).all()


@pytest.mark.parametrize("seed", range(8))
def test_mem_phase_invariants_seeded(seed):
    rng = np.random.default_rng(seed)
    t0 = int(rng.integers(0, 8)) * SCFG.quantum
    req, mem, stats = random_mem_inputs(rng, t0=max(t0, SCFG.quantum))
    check_mem_phase_invariants(req, mem, stats, t0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1 << 16))
def test_mem_phase_invariants_property(seed):
    rng = np.random.default_rng(seed)
    req, mem, stats = random_mem_inputs(rng)
    check_mem_phase_invariants(req, mem, stats, t0=64)


def _quantum_trajectory(n_steps=40):
    """Step the full engine unrolled, yielding state after every quantum."""
    trace = make_workload("hotspot", scale=0.01).kernels[0].pack()
    runner = make_sm_runner(SCFG, "vmap")
    step = jax.jit(lambda s: quantum_step(s, trace, SCFG, DYN, runner))
    state = init_state(SCFG)
    out = [state]
    for _ in range(n_steps):
        state = step(state)
        out.append(state)
    return out


def test_mshr_bounded_and_pending_accounted():
    traj = _quantum_trajectory()
    saw_inflight = False
    for state in traj:
        stage = np.asarray(state["req"]["stage"])
        is_store = np.asarray(state["req"]["is_store"])
        inflight = (stage != 0).sum(axis=1)
        assert (inflight <= SCFG.mshr_per_sm).all()
        saw_inflight |= bool((inflight > 0).any())
        # each non-store in-flight row is exactly one pending load unit
        pending = np.asarray(state["warp"]["pending"]).sum(axis=1)
        loads = ((stage != 0) & ~is_store).sum(axis=1)
        assert (pending == loads).all(), (pending, loads)
    assert saw_inflight, "workload never exercised the MSHRs"


def test_cycle_strictly_monotone_and_done_latches():
    traj = _quantum_trajectory()
    cycles = [int(s["ctrl"]["cycle"]) for s in traj]
    deltas = np.diff(cycles)
    assert (deltas == SCFG.quantum).all(), "clock must advance by Δ/quantum"
    done = [int(s["ctrl"]["done_cycle"]) for s in traj]
    latched = [d for d in done if d >= 0]
    assert all(a == latched[0] for a in latched), "done_cycle must latch once"
