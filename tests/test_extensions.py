"""Extensions: CTA barriers in the simulator + gradient accumulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import BAR, FP32, LDG, TINY
from repro.sim.trace import A_STREAM, KernelTrace, Workload
from repro.workloads import make_workload


def _run(w, mode="vmap"):
    st = simulate(w, TINY, make_sm_runner(TINY, mode), max_cycles=1 << 15)
    return S.comparable(S.finalize(st))


def test_barrier_synchronizes_and_is_deterministic():
    out = _run(make_workload("stencil_bar", scale=0.05))
    assert out["cycles"] > 0 and out["issued"] > 0
    assert out == _run(make_workload("stencil_bar", scale=0.05), "seq")


def test_barrier_delays_fast_warps():
    """A CTA with one slow (memory) warp: barrier forces the compute-only
    warps to wait, so total cycles exceed the no-barrier variant."""
    def kernel(with_bar):
        ops, dep, am, ap = [], [], [], []
        # warp-divergent latency comes from the LDG miss path
        ops += [LDG]
        dep += [True]
        am += [A_STREAM]
        ap += [0]
        ops += [FP32] * 4
        dep += [True] * 4
        am += [0] * 4
        ap += [0] * 4
        if with_bar:
            ops.append(BAR)
            dep.append(False)
            am.append(0)
            ap.append(0)
        ops += [FP32] * 8
        dep += [False] * 8
        am += [0] * 8
        ap += [0] * 8
        tr = KernelTrace("k", n_ctas=2, warps_per_cta=4,
                         ops=np.asarray(ops, np.int32),
                         dep=np.asarray(dep, bool),
                         addr_mode=np.asarray(am, np.int32),
                         addr_param=np.asarray(ap, np.int32))
        return Workload("bar-test", [tr])

    with_bar = _run(kernel(True))
    without = _run(kernel(False))
    assert with_bar["cycles"] >= without["cycles"]
    assert with_bar["issued"] == without["issued"] + 2 * 4  # the BAR issues


def test_grad_accumulation_matches_full_batch():
    """accum_steps=4 over a batch must match the single-shot gradient step
    (same global mean loss => same update, modulo fp32 accumulation)."""
    from repro.configs import ShapeSpec, get_reduced
    from repro.models import factory
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_reduced("minitron-8b")
    shape = ShapeSpec("t", 16, 8, "train")
    opt = OptConfig(peak_lr=1e-2, warmup_steps=0, total_steps=4)
    batch = factory.make_batch(jax.random.PRNGKey(1), cfg, shape)

    s1 = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=16)
    s1, m1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))(s1, batch)
    s4 = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=16)
    s4, m4 = jax.jit(make_train_step(cfg, opt, accum_steps=4))(s4, batch)

    a = jax.tree_util.tree_leaves(s1["params"])
    b = jax.tree_util.tree_leaves(s4["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-3, atol=5e-4)
