"""End-to-end behaviour tests for the framework."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_launcher_loss_improves():
    """~100k-param model, 30 steps on a FIXED repeating batch — the loss
    must drop (end-to-end: data → model → grads → AdamW → schedule)."""
    from repro.configs import ShapeSpec, get_reduced
    from repro.data.pipeline import make_batch_np
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_reduced("codeqwen1.5-7b")
    shape = ShapeSpec("t", 32, 4, "train")
    opt = OptConfig(peak_lr=3e-3, warmup_steps=3, total_steps=40)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=32)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch_np(cfg, shape, seed=0, step=0)
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::6]


def test_serve_generates():
    from repro.configs import get_reduced
    from repro.models.factory import generate
    from repro.models import factory

    cfg = get_reduced("minitron-8b")
    params = factory.init_params(jax.random.PRNGKey(0), cfg, max_seq=24)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out = generate(params, cfg, prompts, max_new=8)
    assert out.shape == (2, 8)
    assert (np.asarray(out) >= 0).all()


def test_simulator_paper_correlation():
    """Fig. 5 insight: long-sim workloads gain most from parallelization —
    lavaMD's modeled speed-up must far exceed myocyte's."""
    from benchmarks.fig5_speedup import modeled_speedup
    from repro.core import stats as S
    from repro.core.engine import simulate
    from repro.core.parallel import make_sm_runner
    from repro.sim.config import RTX3080TI
    from repro.workloads import make_workload

    cfg = RTX3080TI
    ups = {}
    for name in ("lavaMD", "myocyte"):
        st = simulate(make_workload(name, scale=0.02), cfg,
                      make_sm_runner(cfg, "vmap"), max_cycles=1 << 16)
        out = S.finalize(st)
        serial = float(out["l2_hit"] + out["l2_miss"] + out["dram_req"])
        ups[name] = modeled_speedup(
            out["warp_cycles_per_sm"].astype(float), serial, 16, "static",
            cfg)
    assert ups["lavaMD"] > 4.0, ups
    assert ups["myocyte"] < 2.0, ups


def test_dryrun_records_complete():
    """All 40 assigned cells accounted for on both meshes (run + skip)."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run sweep not yet executed")
    recs = []
    for f in os.listdir(d):
        with open(os.path.join(d, f)) as fh:
            recs.append(json.load(fh))
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        assert len(sub) == 40, (mesh, len(sub))
        ok = [r for r in sub if not r.get("skipped") and "error" not in r]
        skipped = [r for r in sub if r.get("skipped")]
        assert len(ok) == 32 and len(skipped) == 8, mesh
        for r in ok:
            assert r["hlo_flops_per_dev"] > 0
            assert r["peak_bytes_per_dev"] > 0
            assert r["dominant"] in ("compute", "memory", "collective")
