"""Sharded-mode determinism (multi-device) — subprocess because jax locks
the host device count at first init.  Covers: device counts, static/dynamic
SM assignment, per-cycle vs windowed exchange."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, jax
    from functools import partial
    from repro.sim.config import TINY, split_config
    from repro.core.engine import run_workload, simulate
    from repro.core.parallel import (make_sm_runner, run_kernel_sharded,
                                     sm_permutation, permute_state)
    from repro.launch.mesh import make_host_mesh
    from repro.core import stats as S
    from repro.sim.state import init_state
    from repro.workloads import make_workload

    cfg = TINY
    scfg, dyn = split_config(cfg)
    w = make_workload("sssp", scale=0.03)
    packed = [k.pack() for k in w.kernels]
    ref = S.comparable(S.finalize(simulate(
        w, cfg, make_sm_runner(cfg, "vmap"), max_cycles=1<<15)))
    results = {"ref": ref}
    for policy in ("static", "dynamic"):
        for exchange in ("window", "cycle"):
            mesh = make_host_mesh(4, "sm")
            perm = sm_permutation(cfg, 4, policy)
            runner = jax.jit(partial(run_kernel_sharded, cfg=cfg, mesh=mesh,
                                     max_cycles=1<<15, exchange=exchange))
            state = run_workload(
                permute_state(init_state(cfg), perm), packed, scfg, dyn,
                kernel_runner=lambda st, k, d: runner(st, k, dyn=d))
            results[f"{policy}/{exchange}"] = S.comparable(S.finalize(state))
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_sharded_identical_to_vmap():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    ref = results.pop("ref")
    for name, got in results.items():
        assert got == ref, (name, got, ref)
