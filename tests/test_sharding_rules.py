"""Sharding-rule coverage: every param/cache leaf of every arch matches a
rule, specs are valid for the production mesh axes, and ZeRO-1 adds the
data axis where legal.  Uses a fake mesh (axis sizes only — no devices)."""
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced, list_archs
from repro.models import factory
from repro.parallelism import sharding as shd
from repro.parallelism.ctx import ShardCtx


@dataclass(frozen=True)
class FakeMesh:
    shape_dict: dict
    @property
    def shape(self):
        return self.shape_dict
    @property
    def axis_names(self):
        return tuple(self.shape_dict)


def make_ctx(multi=False):
    if multi:
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
        return ShardCtx(mesh=mesh, batch_axes=("pod", "data"),
                        tp_axis="model")
    mesh = FakeMesh({"data": 16, "model": 16})
    return ShardCtx(mesh=mesh, batch_axes=("data",), tp_axis="model")


def _check_specs(tree, specs, cfg, ctx):
    flat_x = jax.tree_util.tree_leaves(tree)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_x) == len(flat_s)
    for x, s in zip(flat_x, flat_s):
        assert len(s) <= len(x.shape)
        for entry, dim in zip(tuple(s) + (None,) * 8, x.shape):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= ctx.mesh.shape[a]
            assert dim % size == 0, (x.shape, s)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi", [False, True])
def test_param_rules_cover_all_archs(arch, multi):
    cfg = get_config(arch)
    ctx = make_ctx(multi)
    shapes = jax.eval_shape(
        lambda: factory.init_params(jax.random.PRNGKey(0), cfg,
                                    jnp.bfloat16, max_seq=4096))
    specs = shd.param_pspecs(shapes, cfg, ctx)   # KeyError = missing rule
    _check_specs(shapes, specs, cfg, ctx)
    # ZeRO-1 moments stay divisibility-valid too
    mspecs = shd.moments_pspecs(specs, shapes, ctx)
    _check_specs(shapes, mspecs, cfg, ctx)


@pytest.mark.parametrize("arch", list_archs())
def test_cache_rules_cover_all_archs(arch):
    cfg = get_config(arch)
    ctx = make_ctx()
    for batch, seqlen in ((128, 1024), (1, 4096)):
        shapes = jax.eval_shape(
            lambda: factory.init_cache(cfg, batch, seqlen, jnp.bfloat16))
        specs = shd.cache_pspecs(shapes, cfg, ctx)
        _check_specs(shapes, specs, cfg, ctx)
