"""Typed DynConfig pytree: split-time validation (the legacy flat-dict
default-table shim is GONE — self-contained dicts must supply the
tables), sweep-build-time invariant checks, and the acceptance property
of the table-valued refactor — DEFAULT tables reproduce the untouched
determinism golden bit-exactly while perturbed-table lanes are per-lane
distinct inside the same compiled sweep."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import stats as S
from repro.core.sweep import stack_dyn, sweep
from repro.sim.config import (DISPATCH_OF_CLASS, LATENCY_OF_CLASS, N_CLASSES,
                              TINY, DynConfig, GPUConfig, check_dyn,
                              class_index, split_config, static_part)

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "determinism_tiny.json")
MAX_CYCLES = 1 << 15


def flat_scalars():
    """A legacy flat override dict (scalars + sched, no tables)."""
    d = split_config(TINY)[1].flat()
    return {k: int(v) for k, v in d.items() if k not in ("lat", "disp")}


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def test_split_returns_typed_pytree_with_default_tables():
    scfg, dyn = split_config(TINY)
    assert isinstance(dyn, DynConfig)
    assert tuple(int(v) for v in dyn.core.lat) == LATENCY_OF_CLASS
    assert tuple(int(v) for v in dyn.core.disp) == DISPATCH_OF_CLASS
    # 9 leaves: 2 tables + sched + 2 cache + 3 mem + 1 icnt
    assert len(jax.tree_util.tree_leaves(dyn)) == 9
    # flat() is the exact inverse wire format of from_flat()
    again = DynConfig.from_flat(dyn.flat())
    assert jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda a, b: jnp.array_equal(a, b),
                               dyn, again))


def test_stack_dyn_table_leaf_shapes():
    cfgs = [TINY, dataclasses.replace(TINY, l2_lat=64)]
    _, batch = stack_dyn(cfgs)
    assert batch.core.lat.shape == (2, N_CLASSES)
    assert batch.core.disp.shape == (2, N_CLASSES)
    assert batch.cache.l2_lat.shape == (2,)
    assert [int(v) for v in batch.cache.l2_lat] == [32, 64]


def test_class_index():
    assert class_index("fp32") == 0 and class_index("BAR") == 6
    with pytest.raises(ValueError, match="unknown instruction class"):
        class_index("fp64")


# ---------------------------------------------------------------------------
# split-time validation (satellite: clear ValueError, not downstream KeyError)
# ---------------------------------------------------------------------------

def test_unknown_override_key_named():
    with pytest.raises(ValueError, match=r"unknown.*\['bogus'\]"):
        split_config(TINY, {"bogus": 3})


def test_missing_override_keys_named():
    with pytest.raises(ValueError, match=r"missing.*'icnt_lat'"):
        split_config(static_part(TINY), {"l2_lat": 32, "sched": 0})


def test_table_override_length_checked_at_split():
    with pytest.raises(ValueError, match=r"'lat' must have 7 entries"):
        split_config(TINY, {"lat": (1, 2, 3)})
    with pytest.raises(ValueError, match=r"'disp' must have 7 entries"):
        split_config(TINY, {"disp": list(range(9))})


def test_gpuconfig_table_length_checked():
    with pytest.raises(ValueError, match="lat_of_class must have 7"):
        GPUConfig(lat_of_class=(4, 4))


def test_tableless_flat_dict_rejected():
    """The legacy default-table shim is gone: a self-contained flat dict
    without the per-class 'lat'/'disp' tables raises by name instead of
    silently defaulting them."""
    with pytest.raises(ValueError, match=r"missing.*'disp', 'lat'"):
        split_config(static_part(TINY), flat_scalars())


def test_single_table_override_rejected():
    """'lat' without 'disp' (or vice versa) is never what the caller
    meant — the missing table is named."""
    over = dict(flat_scalars(), lat=LATENCY_OF_CLASS)
    with pytest.raises(ValueError, match=r"missing.*'disp'"):
        split_config(static_part(TINY), over)


def test_full_flat_dict_equals_gpuconfig_route():
    over = dict(flat_scalars(), lat=LATENCY_OF_CLASS,
                disp=DISPATCH_OF_CLASS)
    _, d1 = split_config(static_part(TINY), over)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: jnp.array_equal(a, b), d1, split_config(TINY)[1]))


def test_dynconfig_passthrough():
    scfg, dyn = split_config(TINY)
    scfg2, dyn2 = split_config(scfg, dyn)
    assert scfg2 is scfg and dyn2 is dyn


# ---------------------------------------------------------------------------
# quantum ≤ icnt_lat invariant on the dynamic path (satellite)
# ---------------------------------------------------------------------------

def test_icnt_invariant_enforced_at_split():
    over = dict(flat_scalars(), icnt_lat=TINY.quantum - 1,
                lat=LATENCY_OF_CLASS, disp=DISPATCH_OF_CLASS)
    with pytest.raises(ValueError, match="must be ≤ icnt_lat"):
        split_config(static_part(TINY), over)


def test_icnt_invariant_enforced_at_sweep_build_with_lane():
    """The flat-dict lane route through stack_dyn — the path that used to
    bypass GPUConfig.__post_init__ — is rejected before any trace, naming
    the offending lane."""
    bad = dict(flat_scalars(), icnt_lat=8,
               lat=LATENCY_OF_CLASS, disp=DISPATCH_OF_CLASS)
    with pytest.raises(ValueError, match=r"config lane 1:.*icnt_lat=8"):
        stack_dyn([TINY, (static_part(TINY), bad)])


def test_check_dyn_skips_traced_leaves():
    scfg, dyn = split_config(TINY)

    def f(d):
        check_dyn(scfg, d)      # traced icnt_lat: must not concretize
        return d.icnt.icnt_lat * 1
    assert int(jax.jit(f)(dyn)) == TINY.icnt_lat


def test_stack_dyn_accepts_presplit_lanes():
    """(StaticConfig, overrides) lanes — the raw-table DSE-search route —
    stack against full GPUConfig lanes."""
    scfg = static_part(TINY)
    lat = list(LATENCY_OF_CLASS)
    lat[class_index("fp32")] = 9
    over = dict(flat_scalars(), lat=tuple(lat), disp=DISPATCH_OF_CLASS)
    scfg2, batch = stack_dyn([TINY, (scfg, over)])
    assert scfg2 == scfg
    assert [int(v) for v in batch.core.lat[:, 0]] == [4, 9]


# ---------------------------------------------------------------------------
# acceptance: default tables reproduce the golden; perturbed lanes distinct
# ---------------------------------------------------------------------------

def test_default_table_lane_matches_untouched_golden():
    """One compiled sweep where lane 0 has the default tables and lane 1 a
    perturbed dispatch table: lane 0 must equal the committed golden
    (which predates the table-valued refactor and is NOT regenerated),
    lane 1 must differ — table sweeps explore, defaults stay bit-exact."""
    from repro.workloads import make_workload
    w = make_workload("hotspot", scale=0.02)
    cfgs = [TINY,
            dataclasses.replace(TINY, disp_of_class=(3, 2, 6, 4, 1, 1, 1))]
    result = sweep(w, cfgs, max_cycles=MAX_CYCLES)
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)["hotspot@0.02"]
    assert S.comparable(result.stats[0]) == golden
    assert S.comparable(result.stats[1]) != golden


def test_lat_table_lane_distinct_on_compute_bound_workload():
    """Result-latency perturbation must change a compute-bound lane (the
    memory-bound hotspot golden case is latency-insensitive by design)."""
    from repro.sim.workloads import zoo_workload
    w = zoo_workload("tensor_heavy", scale=0.02)
    cfgs = [TINY,
            dataclasses.replace(TINY, lat_of_class=(24, 12, 48, 32, 0, 0, 1))]
    result = sweep(w, cfgs, max_cycles=MAX_CYCLES)
    assert S.comparable(result.stats[0]) != S.comparable(result.stats[1])
