"""Trace-batching frontend: padding must be INERT.

NOP instruction slots and empty (``n_ctas=0``) pad kernels exist only to
give every workload one shared array shape — they must not change a
single simulated event, a single cycle of accounting, or any stat.
Also covers the ``timeout`` truncation flag (engine accounting).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stats as S
from repro.core.batch import (empty_packed, pad_packed, stack_kernels,
                              stack_workloads)
from repro.core.engine import run_workload_stacked, simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.sim.workloads import zoo_workload

MAX_CYCLES = 1 << 15
SCFG, DYN = split_config(TINY)
RUNNER = make_sm_runner(TINY, "vmap")


def run_stacked(stacked, max_cycles=MAX_CYCLES):
    out = run_workload_stacked(init_state(SCFG), stacked, SCFG, DYN,
                               RUNNER, max_cycles)
    return jax.block_until_ready(out)


def test_padded_equals_unpadded():
    """Extra NOP slots + extra empty kernels: bit-identical final state
    stats, cycles and timeout accounting."""
    w = zoo_workload("mixed", scale=0.02)
    packed = [k.pack() for k in w.kernels]
    plain = run_stacked(stack_kernels(packed))
    n_instr = max(int(k["ops"].shape[0]) for k in packed)
    padded = run_stacked(stack_kernels(packed, n_instr=n_instr + 13,
                                       n_kernels=len(packed) + 3))
    a, b = S.finalize(plain), S.finalize(padded)
    assert S.comparable(a) == S.comparable(b)
    assert a["timeouts"] == b["timeouts"] == 0
    assert int(plain["ctrl"]["total_cycles"]) == \
        int(padded["ctrl"]["total_cycles"])


def test_all_empty_lane_contributes_zero():
    """A lane of nothing but pad kernels: 0 cycles, 0 timeouts, all-zero
    stats, and state untouched (bit-identical to the initial state)."""
    stacked = stack_kernels([empty_packed(8)] * 4)
    out = run_stacked(stacked)
    assert int(out["ctrl"]["total_cycles"]) == 0
    assert int(out["ctrl"]["timeouts"]) == 0
    st = S.finalize(out)
    for k in ("issued", "ctas_launched", "l1_miss", "l2_miss", "dram_req",
              "cycles"):
        assert st[k] == 0, (k, st[k])
    init = init_state(SCFG)
    for part in ("warp", "sm", "req", "mem", "stats_sm", "stats"):
        same = jax.tree_util.tree_map(
            lambda a, b: bool(jnp.array_equal(a, b)), init[part], out[part])
        assert all(jax.tree_util.tree_leaves(same)), part


def test_pad_packed_rejects_shrink():
    k = zoo_workload("streaming_copy", scale=0.02).kernels[0].pack()
    with pytest.raises(ValueError, match="n_instr_max"):
        pad_packed(k, int(k["ops"].shape[0]) - 1)


def test_stack_workloads_shapes():
    ws = [zoo_workload(n, scale=0.02)
          for n in ("mixed", "streaming_copy", "reduction_tree")]
    stacked = stack_workloads(ws)
    n_k = max(len(w.kernels) for w in ws)
    n_i = max(k.n_instr for w in ws for k in w.kernels)
    assert stacked["ops"].shape == (len(ws), n_k, n_i)
    assert stacked["n_ctas"].shape == (len(ws), n_k)
    # pad kernels are flagged empty, real kernels keep their CTA counts
    n_ctas = np.asarray(stacked["n_ctas"])
    for i, w in enumerate(ws):
        assert (n_ctas[i, :len(w.kernels)] > 0).all()
        assert (n_ctas[i, len(w.kernels):] == 0).all()


def test_timeout_flag_reported():
    """A run truncated at max_cycles must say so instead of posing as
    complete; an untruncated run must not."""
    w = zoo_workload("random_gather", scale=0.02)
    cut = S.finalize(simulate(w, TINY, RUNNER, max_cycles=TINY.quantum))
    assert cut["timeout"] and cut["timeouts"] >= 1
    full = S.finalize(simulate(w, TINY, RUNNER, max_cycles=MAX_CYCLES))
    assert not full["timeout"] and full["timeouts"] == 0
