"""Loop-aware HLO cost analyzer: exact on scans, counts in-loop collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_costs


def test_scan_flops_exact():
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    c = hlo_costs.analyze(comp.as_text())
    assert c.flops == 8 * 2 * 16 * 64 * 64
    # XLA's own analysis counts the loop body once — ours must be ≥ 4× it
    xla = comp.cost_analysis()
    if isinstance(xla, (list, tuple)):        # list-of-dicts on older jax
        xla = xla[0] if xla else {}
    assert c.flops > 3 * xla.get("flops", 0)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0].sum()

    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    comp = jax.jit(f).lower(ws, xs).compile()
    c = hlo_costs.analyze(comp.as_text())
    assert c.flops == 4 * 3 * 2 * 8 * 32 * 32


def test_dot_only_flops():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.bfloat16),
        jax.ShapeDtypeStruct((256, 64), jnp.bfloat16)).compile()
    c = hlo_costs.analyze(comp.as_text())
    assert c.flops == 2 * 128 * 256 * 64
    assert c.bytes >= (128 * 256 + 256 * 64 + 128 * 64) * 2
