"""Determinism golden harness — every execution mode, checked two ways.

1. Cross-mode: seq / vmap (and shard when ≥2 devices are visible) must be
   bitwise-identical on the TINY config.
2. Cross-PR: results must ALSO match the committed golden JSON
   (tests/golden/determinism_tiny.json), so a change that breaks timing
   semantics in *all* modes at once — invisible to pairwise comparison —
   still fails loudly.

Regenerate the golden (only after an intentional timing-model change):
    PYTHONPATH=src python tests/test_determinism_matrix.py --regen
"""
import json
import os
from functools import partial

import jax
import pytest

from repro.core import stats as S
from repro.core.engine import run_workload, simulate
from repro.core.parallel import (make_sm_runner, permute_state,
                                 run_kernel_sharded, sm_permutation)
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.workloads import make_workload

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "determinism_tiny.json")
# "zoo:" prefix loads from the sweep-facing workload zoo (sim/workloads.py)
# so the batched frontend — padding, kernel-axis scan, zoo generators — is
# locked cross-mode and cross-PR alongside the Table-2 analogues.
# "trace:" loads a bundled Accel-sim SASS trace fixture through the full
# ingest pipeline (sim/traceio.py: parse → address fit → KernelTrace), so
# real-trace-derived workloads are locked cross-mode and cross-PR too —
# a parser/fitter change that shifts any lowered value fails here.
CASES = (("hotspot", 0.02), ("myocyte", 1.0), ("zoo:mixed", 0.03),
         ("trace:gather_chain", 1.0))
MAX_CYCLES = 1 << 15


def load_case(bench, scale):
    if bench.startswith("zoo:"):
        from repro.sim.workloads import zoo_workload
        return zoo_workload(bench[len("zoo:"):], scale=scale)
    if bench.startswith("trace:"):
        # auto-registers from the bundled tests/data/traces fixtures
        from repro.sim.workloads import zoo_workload
        return zoo_workload(bench, scale=scale)
    return make_workload(bench, scale=scale)


def run_mode(workload, mode):
    return S.comparable(S.finalize(simulate(
        workload, TINY, make_sm_runner(TINY, mode), max_cycles=MAX_CYCLES)))


def run_shard(workload, n_dev, policy="static", exchange="window"):
    from repro.launch.mesh import make_host_mesh
    cfg = TINY
    scfg, dyn = split_config(cfg)
    mesh = make_host_mesh(n_dev, "sm")
    state = permute_state(init_state(cfg), sm_permutation(cfg, n_dev, policy))
    runner = jax.jit(partial(run_kernel_sharded, cfg=cfg, mesh=mesh,
                             max_cycles=MAX_CYCLES, exchange=exchange))
    state = run_workload(
        state, [k.pack() for k in workload.kernels], scfg, dyn,
        kernel_runner=lambda st, packed, d: runner(st, packed, dyn=d))
    return S.comparable(S.finalize(state))


def load_golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("bench,scale", CASES)
def test_matrix_bitexact_and_golden(bench, scale):
    w = load_case(bench, scale)
    results = {m: run_mode(w, m) for m in ("seq", "vmap")}
    if len(jax.devices()) >= 2:
        n_dev = max(d for d in range(2, len(jax.devices()) + 1)
                    if TINY.n_sm % d == 0)
        results[f"shard{n_dev}"] = run_shard(w, n_dev)
    ref = results["vmap"]
    for mode, got in results.items():
        assert got == ref, f"mode {mode} diverged: {got} != {ref}"
    golden = load_golden()[f"{bench}@{scale}"]
    assert ref == golden, (
        f"stats drifted from committed golden for {bench}@{scale} — if the "
        f"timing model changed intentionally, regenerate with --regen.\n"
        f"got:    {ref}\ngolden: {golden}")


def test_golden_covers_all_cases():
    golden = load_golden()
    assert set(golden) == {f"{b}@{s}" for b, s in CASES}
    for stats in golden.values():
        # exactly the comparable key set, no extras and none missing
        assert S.comparable(stats) == stats


def _regen():
    golden = {}
    for bench, scale in CASES:
        w = load_case(bench, scale)
        seq, vm = run_mode(w, "seq"), run_mode(w, "vmap")
        assert seq == vm, (bench, seq, vm)
        golden[f"{bench}@{scale}"] = vm
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
