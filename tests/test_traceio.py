"""Trace-ingestion conformance suite (sim/traceio.py).

Locks the Accel-sim SASS trace subset parser → ``KernelTrace`` IR →
simulator pipeline three ways:

1. **Golden parses** of every bundled fixture (tests/data/traces/*):
   opcode class sequences, dep chains, CTA/warp shapes and fitted
   address knobs pinned as literals — a format or fitter change that
   shifts any lowered value fails here first.
2. **Malformed-input errors**: every rejected construct raises
   ``TraceFormatError`` naming the offending line number.
3. **Round-trip**: ``KernelTrace`` → synthesized subset text → parse →
   lower → bit-equal IR, for the fixtures and real zoo workloads.

Plus hypothesis property tests (random trace generator → invariants /
round-trip) via the optional-hypothesis shim in tests/_hyp.py.
"""
import os

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.sim import traceio
from repro.sim.config import (BAR, FP32, INT32, LDG, N_CLASSES, SFU, STG,
                              TENSOR, TINY)
from repro.sim.trace import (A_NONE, A_RANDOM, A_STREAM, A_STRIDED,
                             KernelTrace, Workload)
from repro.sim.traceio import (TraceFormatError, classify_opcode,
                               lower_kernel, parse_trace_text)
from repro.sim.workloads import (TRACE_INGESTS, register_traces,
                                 zoo_workload)

TRACE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "data", "traces")


def load(name):
    return traceio.load_trace(os.path.join(TRACE_DIR, name + ".trace"))


# ---------------------------------------------------------------------------
# 1. golden parses of the bundled fixtures
# ---------------------------------------------------------------------------

def test_vecadd_golden():
    ing = load("vecadd")
    assert len(ing.workload.kernels) == 1
    k = ing.workload.kernels[0]
    assert (k.name, k.n_ctas, k.warps_per_cta) == ("vecadd", 4, 2)
    assert k.ops.tolist() == [LDG, LDG, FP32, STG]
    assert k.dep.tolist() == [False, False, True, True]
    assert k.addr_mode.tolist() == [A_STREAM, A_STREAM, A_NONE, A_STREAM]
    assert k.addr_param.tolist() == [1, 5, 0, 9]
    fit = ing.fits[0]
    assert fit.n_mem == 3
    assert fit.n_warps_seen == 8 and fit.divergent_warps == 0
    assert fit.dropped == {"EXIT": 8}


def test_mm_tile_golden():
    ing = load("mm_tile")
    k = ing.workload.kernels[0]
    assert (k.name, k.n_ctas, k.warps_per_cta) == ("mm_tile", 6, 4)
    assert k.ops.tolist() == [LDG, LDG, TENSOR, TENSOR,
                              LDG, LDG, TENSOR, TENSOR, STG]
    assert k.dep.tolist() == [False, False, True, True,
                              False, False, True, True, False]
    assert k.addr_mode.tolist() == [A_STRIDED, A_STRIDED, A_NONE, A_NONE,
                                    A_STRIDED, A_STRIDED, A_NONE, A_NONE,
                                    A_STREAM]
    assert k.addr_param.tolist() == [2, 66, 0, 0, 2, 66, 0, 0, 100]
    assert ing.fits[0].fit_err == [0.0] * 5      # exact on all 5 mem ops


def test_gather_chain_golden():
    """Multi-kernel file: kernels lower in file order; random-address
    params recover exactly; the barrier kernel keeps its BAR op."""
    ing = load("gather_chain")
    gather, reduce_k = ing.workload.kernels
    assert (gather.name, gather.n_ctas, gather.warps_per_cta) == \
        ("gather", 4, 1)
    assert gather.ops.tolist() == [LDG, INT32, LDG, INT32, STG]
    assert gather.dep.tolist() == [False, True, True, True, False]
    assert gather.addr_mode.tolist() == [A_RANDOM, A_NONE, A_RANDOM,
                                         A_NONE, A_RANDOM]
    assert gather.addr_param.tolist() == [3, 0, 7, 0, 11]
    assert (reduce_k.name, reduce_k.n_ctas, reduce_k.warps_per_cta) == \
        ("reduce", 2, 2)
    assert reduce_k.ops.tolist() == [LDG, LDG, FP32, BAR, STG]
    assert reduce_k.dep.tolist() == [False, False, True, False, False]
    # reduce's first LDG is a mode-0 per-thread address LIST in the file;
    # only the base is consumed, so the fit still recovers (stream, 0)
    assert reduce_k.addr_mode.tolist() == [A_STREAM, A_STREAM, A_NONE,
                                           A_NONE, A_STREAM]
    assert reduce_k.addr_param.tolist() == [0, 1, 0, 0, 2]


def test_fit_error_recorded():
    """vecadd's second load is deliberately perturbed (+1 block on odd
    gwarps) in the fixture: the fit stays A_STREAM with the true param
    but records the error instead of silently pretending exactness."""
    ing = load("vecadd")
    fit = ing.fits[0]
    assert fit.fit_err == [0.0, 0.5, 0.0]
    assert fit.fit_err_mean == pytest.approx(1 / 6)
    assert fit.fit_err_max == 0.5
    s = ing.summary()
    assert s["fit_err_max"] == 0.5 and s["n_kernels"] == 1


def test_extra_headers_tolerated():
    """Unrecognized '-key = value' headers are recorded and dropped, not
    fatal (nvbit version, tracer version, base addrs...)."""
    parsed = traceio.parse_trace_file(
        os.path.join(TRACE_DIR, "vecadd.trace"))
    assert len(parsed) == 1
    pk = parsed[0]
    assert pk.grid == (4, 1, 1) and pk.block == (64, 1, 1)
    assert "nvbit version" in pk.extras
    assert "accelsim tracer version" in pk.extras


# ---------------------------------------------------------------------------
# 2. malformed input → TraceFormatError naming the line
# ---------------------------------------------------------------------------

HDR = "-kernel name = k\n-grid dim = (2,1,1)\n-block dim = (32,1,1)\n"
TB = "#BEGIN_TB\nthread block = 0,0,0\nwarp = 0\n"


@pytest.mark.parametrize("text,match,line_no", [
    (HDR.replace("(2,1,1)", "(2,1)"), "expected dimension tuple", 2),
    ("0000 ffffffff 1 R2 FFMA 1 R1 0\n", "unexpected line", 1),
    (HDR + TB + "zz00 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n",
     "expected hex PC", 7),
    (HDR + TB + "0000 ffffffff 2 R2 FFMA 1 R1 0\n#END_TB\n",
     "expected register operand", 7),
    (HDR + TB + "insts = 3\n0000 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n",
     "declared insts = 3 but has 1", 9),
    (HDR + TB + "0000 ffffffff 1 R2 LDG.E 1 R1 4 7 0x80 4\n#END_TB\n",
     "unsupported address compression mode 7", 7),
    (HDR + TB + "0000 ffffffff 1 R2 LDG.E 1 R1 4\n#END_TB\n",
     "missing its address info", 7),
    ("#BEGIN_TB\n", "kernel header incomplete", 1),
    (HDR + "warp = 0\n", "outside #BEGIN_TB", 4),
    (HDR + TB + "0000 ffffffff 1 R2 FFMA 1 R1 0 junk\n#END_TB\n",
     "unexpected trailing tokens", 7),
    (HDR + TB + "0000 ffffffff 1 R2 FFMA 1 R1 0\n",
     "unterminated #BEGIN_TB", 7),
    (HDR + "#BEGIN_TB\nthread block = 5,0,0\n",
     "outside grid", 5),
    (HDR.replace("(2,1,1)", "(1,1,1)") + TB
     + "0000 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n"
     + TB + "0000 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n",
     "more thread blocks than grid size 1", 13),
    ("", "no kernels found", None),
])
def test_malformed_input(text, match, line_no):
    with pytest.raises(TraceFormatError, match=match) as exc:
        parse_trace_text(text, path="bad.trace")
    assert exc.value.line_no == line_no
    if line_no is not None:
        assert f"bad.trace:{line_no}" in str(exc.value)


def test_error_message_names_path_and_line():
    err = TraceFormatError("boom", line_no=7, path="x.trace")
    assert str(err) == "x.trace:7: boom"
    assert isinstance(err, ValueError)


# ---------------------------------------------------------------------------
# 3. round-trip: IR → synthesized text → parse → equal IR
# ---------------------------------------------------------------------------

def _roundtrip(workload):
    text = traceio.synthesize_trace(workload)
    parsed = parse_trace_text(text, path="<synth>")
    assert len(parsed) == len(workload.kernels)
    for pk, orig in zip(parsed, workload.kernels):
        kt, _fit = lower_kernel(pk)
        assert kt == orig, orig.name


def test_roundtrip_fixtures():
    for name in ("mm_tile", "gather_chain"):
        _roundtrip(load(name).workload)


def test_roundtrip_zoo_workloads():
    """Real zoo generators survive the full loop: their procedural
    address knobs (stream/strided/random, params < 1024) are recovered
    bit-exactly from the synthesized address streams."""
    for name in ("gemm_tiled", "random_gather", "reduction_tree"):
        _roundtrip(zoo_workload(name, scale=0.01))


def test_random_param_recovered_exactly():
    k = KernelTrace("r", 2, 2, np.array([LDG], np.int32),
                    np.array([False]), np.array([A_RANDOM], np.int32),
                    np.array([777], np.int32))
    _roundtrip(Workload("r", [k]))


# ---------------------------------------------------------------------------
# classification / lowering details
# ---------------------------------------------------------------------------

def test_classify_opcode_table():
    assert classify_opcode("LDG.E.SYS") == LDG
    assert classify_opcode("STG.E") == STG
    assert classify_opcode("ATOMG.ADD") == STG
    assert classify_opcode("FFMA") == FP32
    assert classify_opcode("HFMA2.MMA") == FP32
    assert classify_opcode("IMAD.MOV.U32") == INT32
    assert classify_opcode("MUFU.RCP") == SFU
    assert classify_opcode("HMMA.1688.F32") == TENSOR
    assert classify_opcode("BAR.SYNC") == BAR
    assert classify_opcode("MEMBAR.GPU") == BAR
    assert classify_opcode("EXIT") is None          # dropped
    assert classify_opcode("BRA") == INT32          # control issues as ALU
    assert classify_opcode("LDS.U") == INT32        # shmem: no DRAM traffic


def test_shmem_and_unknown_ops_counted():
    text = (HDR + TB
            + "0000 ffffffff 1 R2 LDS.U 1 R1 4 1 0x100 4\n"
            + "0010 ffffffff 1 R3 FROBNICATE 1 R2 0\n"
            + "#END_TB\n")
    pk = parse_trace_text(text)[0]
    kt, fit = lower_kernel(pk)
    assert kt.ops.tolist() == [INT32, INT32]
    assert kt.dep.tolist() == [False, True]
    assert fit.shmem_ops == 1 and fit.unknown_ops == 1
    # shmem base addresses are NOT fitted: only LDG/STG classes hit DRAM
    assert fit.n_mem == 0 and kt.addr_mode.tolist() == [A_NONE, A_NONE]


def test_divergent_warp_excluded_from_fit():
    text = (HDR
            + "#BEGIN_TB\nthread block = 0,0,0\n"
            + "warp = 0\n0000 ffffffff 1 R2 FFMA 1 R1 0\n"
            + "#END_TB\n"
            + "#BEGIN_TB\nthread block = 1,0,0\n"
            + "warp = 0\n0000 ffffffff 1 R2 IMAD 1 R1 0\n"
            + "#END_TB\n")
    kt, fit = lower_kernel(parse_trace_text(text)[0])
    assert kt.ops.tolist() == [FP32]     # canonical = thread block 0
    assert fit.divergent_warps == 1 and fit.n_warps_seen == 2


def test_dep_ignores_zero_register():
    """R255 (RZ) always reads zero — writing then reading it is not a
    dependency."""
    text = (HDR + TB
            + "0000 ffffffff 1 R255 FFMA 1 R1 0\n"
            + "0010 ffffffff 1 R3 FFMA 1 R255 0\n"
            + "#END_TB\n")
    kt, _ = lower_kernel(parse_trace_text(text)[0])
    assert kt.dep.tolist() == [False, False]


def test_cta_split_for_oversized_blocks():
    """A 1024-thread CTA (32 warps) splits into 4 CTAs of 8 warps under
    max_warps_per_cta=8, preserving the total warp count."""
    text = HDR.replace("(32,1,1)", "(1024,1,1)") + TB + \
        "0000 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n"
    pk = parse_trace_text(text)[0]
    kt, fit = lower_kernel(pk, max_warps_per_cta=8)
    assert (kt.n_ctas, kt.warps_per_cta) == (8, 8)   # 2 CTAs × split 4
    assert fit.cta_split == 4
    kt2, _ = lower_kernel(pk)
    assert (kt2.n_ctas, kt2.warps_per_cta) == (2, 32)


def test_oversized_cta_rejected_before_simulation():
    """core/batch.py:check_workload_fits: a kernel whose CTA exceeds the
    SM's warp slots is rejected by name instead of spinning to
    max_cycles."""
    from repro.core.parallel import make_sm_runner
    from repro.core.engine import simulate
    from repro.core.sweep import grid_sweep

    text = HDR.replace("(32,1,1)", "(1024,1,1)") + TB + \
        "0000 ffffffff 1 R2 FFMA 1 R1 0\n#END_TB\n"
    kt, _ = lower_kernel(parse_trace_text(text)[0])
    w = Workload("trace:big", [kt])
    with pytest.raises(ValueError, match="warps_per_cta=32 > warps_per_sm"):
        simulate(w, TINY, make_sm_runner(TINY, "vmap"), max_cycles=1 << 10)
    with pytest.raises(ValueError, match="max_warps_per_cta"):
        grid_sweep([w], [TINY], max_cycles=1 << 10)


# ---------------------------------------------------------------------------
# zoo registration
# ---------------------------------------------------------------------------

def test_zoo_registration_and_scaling():
    names = register_traces(TRACE_DIR)
    assert names == ["trace:gather_chain", "trace:mm_tile", "trace:vecadd"]
    assert set(names) <= set(TRACE_INGESTS)
    w = zoo_workload("trace:vecadd")               # real grid by default
    assert w.name == "trace:vecadd"
    assert [k.n_ctas for k in w.kernels] == [4]
    half = zoo_workload("trace:vecadd", scale=0.5)
    assert [k.n_ctas for k in half.kernels] == [2]
    with pytest.raises(FileNotFoundError, match="no .trace files"):
        register_traces(os.path.dirname(TRACE_DIR))   # dir without traces


def test_zoo_trace_autoregister_and_unknown():
    """'trace:<x>' resolves from the search path without explicit
    registration; unknown names still raise the zoo KeyError."""
    from repro.sim import workloads as Z

    Z.ZOO.pop("trace:mm_tile", None)
    Z.TRACE_INGESTS.pop("trace:mm_tile", None)
    w = zoo_workload("trace:mm_tile")              # bundled fixture dir
    assert w.kernels[0].name == "mm_tile"
    with pytest.raises(KeyError, match="unknown zoo workload"):
        zoo_workload("trace:no_such_fixture")


def test_resolve_workload_namespaces():
    from repro.sim.workloads import resolve_workload

    assert resolve_workload("trace:vecadd").name == "trace:vecadd"
    assert resolve_workload("zoo:mixed", 0.02).name == "mixed"
    assert resolve_workload("gemm_tiled", 0.02).name == "gemm_tiled"
    assert resolve_workload("hotspot", 0.02).name == "hotspot"


# ---------------------------------------------------------------------------
# CLI (launch/trace_ingest.py)
# ---------------------------------------------------------------------------

def test_trace_ingest_cli(tmp_path, capsys):
    import json

    from repro.launch.trace_ingest import main

    vec = os.path.join(TRACE_DIR, "vecadd.trace")
    assert main(["inspect", vec]) == 0
    out = capsys.readouterr().out
    assert "kernel 'vecadd'" in out and "classes" in out

    assert main(["summarize", vec]) == 0
    s = json.loads(capsys.readouterr().out)
    assert s["n_kernels"] == 1 and s["fit_err_max"] == 0.5

    dst = str(tmp_path / "vecadd.json")
    assert main(["convert", vec, "-o", dst]) == 0
    capsys.readouterr()
    with open(dst) as f:
        ir = json.load(f)
    assert ir["kernels"][0]["ops"] == [LDG, LDG, FP32, STG]

    assert main(["roundtrip", vec]) == 0
    assert "roundtrip OK" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# property tests: random trace generator → parse → lowered invariants
# ---------------------------------------------------------------------------

def _instr_strategy():
    mem = st.tuples(st.sampled_from([LDG, STG]), st.booleans(),
                    st.sampled_from([A_STREAM, A_STRIDED, A_RANDOM]),
                    st.integers(min_value=0, max_value=1023))
    alu = st.tuples(st.sampled_from([FP32, INT32, SFU, TENSOR, BAR]),
                    st.booleans(), st.just(A_NONE), st.just(0))
    return st.one_of(mem, alu)


def _kernel_strategy():
    return st.builds(
        lambda body, n_ctas, wpc: KernelTrace(
            "prop", n_ctas, wpc,
            np.array([b[0] for b in body], np.int32),
            np.array([False] + [b[1] for b in body[1:]], bool),
            np.array([b[2] for b in body], np.int32),
            np.array([b[3] for b in body], np.int32)),
        st.lists(_instr_strategy(), min_size=1, max_size=8),
        st.integers(min_value=1, max_value=3),
        st.integers(min_value=1, max_value=4))


@settings(max_examples=25, deadline=None)
@given(_kernel_strategy())
def test_prop_lowered_invariants(kernel):
    """Any generated trace parses back to a KernelTrace whose fields
    satisfy the IR invariants the engine relies on."""
    text = traceio.synthesize_kernel(kernel)
    kt, fit = lower_kernel(parse_trace_text(text)[0])
    assert kt.n_instr == len(kt.ops) == len(kt.dep) \
        == len(kt.addr_mode) == len(kt.addr_param)
    assert kt.n_instr == kernel.n_instr
    assert not kt.dep[0]
    assert (kt.ops >= 0).all() and (kt.ops < N_CLASSES).all()
    assert (kt.addr_param >= 0).all()
    assert (kt.addr_mode >= 0).all() and (kt.addr_mode <= A_RANDOM).all()
    assert kt.n_ctas >= 1 and kt.warps_per_cta >= 1
    assert fit.n_mem == int(np.isin(kt.ops, (LDG, STG)).sum())


@settings(max_examples=25, deadline=None)
@given(_kernel_strategy())
def test_prop_roundtrip(kernel):
    """Generated traces with ≥2 gwarps round-trip to the identical IR
    (single-gwarp linear fits are inherently ambiguous — documented)."""
    if kernel.n_ctas * kernel.warps_per_cta < 2:
        kernel = KernelTrace(kernel.name, 2, kernel.warps_per_cta,
                             kernel.ops, kernel.dep, kernel.addr_mode,
                             kernel.addr_param)
    _roundtrip(Workload("prop", [kernel]))
