"""Per-arch reduced smoke tests: one train step + one decode step on CPU,
asserting output shapes and finiteness (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeSpec, get_reduced, list_archs
from repro.models import factory
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_reduced(arch)
    shape = ShapeSpec("t", 32, 2, "train")
    opt = OptConfig(warmup_steps=1, total_steps=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=32)
    step = jax.jit(make_train_step(cfg, opt))
    batch = factory.make_batch(jax.random.PRNGKey(1), cfg, shape)
    state, metrics = step(state, batch)   # step 0: lr=0 (warmup)
    state, metrics = step(state, batch)   # step 1: lr>0 — params move
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(state["step"]) == 2
    # params actually changed
    leaves0 = jax.tree_util.tree_leaves(
        init_train_state(jax.random.PRNGKey(0), cfg, opt,
                         max_seq=32)["params"])
    leaves1 = jax.tree_util.tree_leaves(state["params"])
    assert any(bool(jnp.any(a != b)) for a, b in zip(leaves0, leaves1))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_step(arch):
    cfg = get_reduced(arch)
    b, s = 2, 16
    params = factory.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    batch = factory.make_batch(jax.random.PRNGKey(1), cfg,
                               ShapeSpec("p", s, b, "prefill"))
    logits, cache = factory.prefill(params, batch, cfg=cfg, max_len=32)
    assert logits.shape == (b, cfg.padded_vocab(32))
    assert jnp.isfinite(logits).all()
    db = factory.make_decode_batch(jax.random.PRNGKey(2), cfg, b)
    logits2, cache2 = factory.decode(params, cache, db, cfg=cfg)
    assert jnp.isfinite(logits2).all()
    assert int(cache2["len"][0]) == s + 1


@pytest.mark.parametrize("arch", ["arctic-480b", "qwen2-72b", "rwkv6-1.6b",
                                  "whisper-base", "jamba-v0.1-52b"])
def test_cache_consistency(arch):
    """decode-from-cache ≡ teacher-forced prefill (no-drop capacity)."""
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b, s = 2, 16
    params = factory.init_params(jax.random.PRNGKey(0), cfg, max_seq=s)
    batch = factory.make_batch(jax.random.PRNGKey(1), cfg,
                               ShapeSpec("p", s, b, "prefill"))
    full_logits, _ = factory.prefill(params, batch, cfg=cfg, max_len=s)
    if "tokens" in batch:
        b1 = dict(batch, tokens=batch["tokens"][:, :s - 1])
        db = {"tokens": batch["tokens"][:, s - 1:s]}
    else:
        b1 = dict(batch, embeds=batch["embeds"][:, :s - 1])
        db = {"embeds": batch["embeds"][:, s - 1:s]}
    _, cache = factory.prefill(params, b1, cfg=cfg, max_len=s)
    dec_logits, _ = factory.decode(params, cache, db, cfg=cfg)
    assert float(jnp.max(jnp.abs(full_logits - dec_logits))) < 2e-3
