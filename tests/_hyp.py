"""Optional-hypothesis shim: property tests degrade to skips, everything
else in the module keeps running, when `hypothesis` is not installed
(it is an optional extra in requirements-dev.txt)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return deco
