"""Fault tolerance: checkpoint/restore, restart-equivalence, async saver."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing.checkpoint import (AsyncSaver, latest_step, restore,
                                            save)
from repro.configs import ShapeSpec, get_reduced
from repro.data.pipeline import make_batch_np
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def _train(cfg, opt, state, step_fn, shape, start, n):
    for step in range(start, start + n):
        batch = make_batch_np(cfg, shape, seed=7, step=step)
        state, _ = step_fn(state, batch)
    return state


def test_restart_bit_identical(tmp_path):
    """train 6 straight  ==  train 3, checkpoint, crash, restore, train 3."""
    cfg = get_reduced("minitron-8b")
    shape = ShapeSpec("t", 32, 2, "train")
    opt = OptConfig(warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(cfg, opt))

    s0 = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=32)
    straight = _train(cfg, opt, s0, step_fn, shape, 0, 6)

    s1 = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=32)
    s1 = _train(cfg, opt, s1, step_fn, shape, 0, 3)
    save(str(tmp_path), 3, s1)
    del s1                                     # "crash"
    assert latest_step(str(tmp_path)) == 3
    like = init_train_state(jax.random.PRNGKey(1), cfg, opt, max_seq=32)
    s2 = restore(str(tmp_path), 3, like)
    resumed = _train(cfg, opt, s2, step_fn, shape, 3, 3)

    a = jax.tree_util.tree_leaves(straight["params"])
    b = jax.tree_util.tree_leaves(resumed["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async_saver(tmp_path):
    cfg = get_reduced("whisper-base")
    opt = OptConfig()
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, max_seq=16)
    saver = AsyncSaver()
    saver.save_async(str(tmp_path), 1, state)
    saver.wait()
    assert latest_step(str(tmp_path)) == 1
    got = restore(str(tmp_path), 1, state)
    for x, y in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_data_pipeline_deterministic():
    cfg = get_reduced("codeqwen1.5-7b")
    shape = ShapeSpec("t", 16, 2, "train")
    a = make_batch_np(cfg, shape, seed=3, step=11)
    b = make_batch_np(cfg, shape, seed=3, step=11)
    c = make_batch_np(cfg, shape, seed=3, step=12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
