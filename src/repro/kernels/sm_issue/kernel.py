"""Warp issue-selection as a Pallas TPU kernel.

Grid: (n_sm,) — one SM's warp state per program instance, SoA int32 arrays
resident in VMEM (48 warps × a few fields ≈ 1 KB: the whole working set of
the simulator's hot phase fits on-chip, which is exactly why the SM loop
vectorizes so well on TPU).  Sub-cores unroll as a static python loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sim.config import N_UNITS, UNIT_OF_CLASS

BIG = jnp.int32(1 << 30)


def _issue_kernel(pc_ref, act_ref, rdy_ref, pend_ref, wait_ref, last_ref,
                  uf_ref, ops_ref, dep_ref, unit_tab_ref, t_ref, sel_ref, *,
                  n_subcores: int, n_warps: int, n_instr: int):
    t = t_ref[0]
    ops = ops_ref[...]
    unit_tab = unit_tab_ref[...]
    big = 1 << 30
    for sc in range(n_subcores):
        w_ids = sc + n_subcores * jax.lax.iota(jnp.int32,
                                               n_warps // n_subcores)
        pcs = pc_ref[0, :][w_ids]
        exists = (act_ref[0, :][w_ids] != 0) & (pcs < n_instr)
        blocked = (wait_ref[0, :][w_ids] != 0) & (pend_ref[0, :][w_ids] > 0)
        ready = exists & ~blocked & (rdy_ref[0, :][w_ids] <= t)
        op = ops[jnp.clip(pcs, 0, n_instr - 1)]
        unit = unit_tab[op]
        ufree = uf_ref[0, sc, :][unit] <= t
        cand = ready & ufree
        greedy = w_ids == last_ref[0, sc]
        key = jnp.where(cand, jnp.where(greedy, -big, w_ids), big)
        idx = jnp.argmin(key)
        sel_ref[0, sc] = jnp.where(cand[idx], w_ids[idx], -1)


def issue_select_pallas(pc, active, ready_at, pending, wait_mem, last_issued,
                        unit_free, ops, dep, t, *, n_subcores: int,
                        interpret: bool = True):
    n_sm, w = pc.shape
    L = ops.shape[0]
    sc = n_subcores

    def smmap(i):
        return (i, 0)

    def scmap(i):
        return (i, 0, 0)

    def full(i):
        return (0,)

    kern = functools.partial(_issue_kernel, n_subcores=sc, n_warps=w,
                             n_instr=L)
    return pl.pallas_call(
        kern,
        grid=(n_sm,),
        in_specs=[
            pl.BlockSpec((1, w), smmap),          # pc
            pl.BlockSpec((1, w), smmap),          # active
            pl.BlockSpec((1, w), smmap),          # ready_at
            pl.BlockSpec((1, w), smmap),          # pending
            pl.BlockSpec((1, w), smmap),          # wait_mem
            pl.BlockSpec((1, sc), smmap),         # last_issued
            pl.BlockSpec((1, sc, N_UNITS), scmap),  # unit_free
            pl.BlockSpec((L,), full),             # ops
            pl.BlockSpec((L,), full),             # dep
            pl.BlockSpec((len(UNIT_OF_CLASS),), full),  # unit table
            pl.BlockSpec((1,), full),             # t
        ],
        out_specs=pl.BlockSpec((1, sc), smmap),
        out_shape=jax.ShapeDtypeStruct((n_sm, sc), jnp.int32),
        interpret=interpret,
    )(pc, active.astype(jnp.int32), ready_at, pending,
      wait_mem.astype(jnp.int32), last_issued, unit_free, ops,
      dep.astype(jnp.int32), jnp.asarray(UNIT_OF_CLASS, jnp.int32),
      jnp.asarray([t], jnp.int32))
