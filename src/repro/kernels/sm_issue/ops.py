"""Jit'd public wrapper for the sm_issue kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sm_issue.kernel import issue_select_pallas
from repro.kernels.sm_issue.ref import issue_select_ref


@partial(jax.jit, static_argnames=("n_subcores", "interpret"))
def issue_select_op(pc, active, ready_at, pending, wait_mem, last_issued,
                    unit_free, ops, dep, t, *, n_subcores: int,
                    interpret: bool = True):
    return issue_select_pallas(pc, active, ready_at, pending, wait_mem,
                               last_issued, unit_free, ops, dep, t,
                               n_subcores=n_subcores, interpret=interpret)
