"""Pure-jnp oracle: per-sub-core warp readiness + GTO selection.

This is the >93% hot phase of the simulator (paper Fig. 4) distilled to its
selection math: for every SM and sub-core, build the candidate mask
(active ∧ pc in range ∧ not memory-blocked ∧ scoreboard-ready ∧ dispatch
port free) and pick the GTO winner (greedy = last-issued warp first, then
oldest = lowest warp id).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.sim.config import LDG, N_UNITS, STG, UNIT_OF_CLASS

BIG = jnp.int32(1 << 30)


def issue_select_ref(pc, active, ready_at, pending, wait_mem, last_issued,
                     unit_free, ops, dep, t, *, n_subcores: int):
    """Shapes: pc/active/ready_at/pending/wait_mem: (n_sm, W);
    last_issued: (n_sm, SC); unit_free: (n_sm, SC, NU);
    ops/dep: (L,); t: scalar.  Returns sel: (n_sm, SC) int32 (-1 = none)."""
    n_sm, w = pc.shape
    L = ops.shape[0]
    sels = []
    for sc in range(n_subcores):
        w_ids = jnp.arange(sc, w, n_subcores, dtype=jnp.int32)
        pcs = pc[:, w_ids]
        exists = active[:, w_ids] & (pcs < L)
        blocked = wait_mem[:, w_ids] & (pending[:, w_ids] > 0)
        ready = exists & ~blocked & (ready_at[:, w_ids] <= t)
        op = ops[jnp.clip(pcs, 0, L - 1)]
        unit = jnp.asarray(UNIT_OF_CLASS, jnp.int32)[op]
        ufree = jnp.take_along_axis(unit_free[:, sc, :], unit, axis=1) <= t
        cand = ready & ufree
        greedy = w_ids[None, :] == last_issued[:, sc:sc + 1]
        key = jnp.where(cand, jnp.where(greedy, -1, w_ids[None, :]), BIG)
        idx = jnp.argmin(key, axis=1)
        any_c = jnp.take_along_axis(cand, idx[:, None], axis=1)[:, 0]
        sels.append(jnp.where(any_c, w_ids[idx], -1))
    return jnp.stack(sels, axis=1)
