"""Pure-jnp oracles for the RWKV-6 wkv kernel.

``wkv_ref_stepwise`` is the literal per-token recurrence (ground truth);
``wkv_ref_chunked`` re-exports the layer's chunked-parallel form (used in
the model).  Tests assert kernel ≡ chunked ≡ stepwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.rwkv6 import wkv_chunked as wkv_ref_chunked  # noqa: F401


def wkv_ref_stepwise(r, k, v, wlog, u, state):
    """r,k,v,wlog: (B,S,H,hs); u: (H,hs); state: (B,H,hs,hs) fp32."""
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = wlog.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs            # (B,H,hs)
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhi,bhij->bhj", rt, S + uf[..., None] * kv)
        S = jnp.exp(wt)[..., None] * S + kv
        return S, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state, o = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1), state
