"""RWKV-6 wkv chunked linear attention as a Pallas TPU kernel.

Grid: (B, H, n_chunks) — chunks iterate sequentially, the (hs × hs) wkv
state lives in VMEM scratch.  Per chunk: inter-chunk term via an MXU matmul
against the carried state, intra-chunk term via pairwise bounded decays
(all exponents ≤ 0 ⇒ fp32-safe), then the state update.  Chunk size 64 ×
head size 64 keeps the (C,C,hs) decay tensor at 1 MB fp32 in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_sc, *,
                chunk: int, hs: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_sc[...] = jnp.zeros_like(s_sc)

    rr = r_ref[0, 0].astype(jnp.float32)      # (C, hs)
    kk = k_ref[0, 0].astype(jnp.float32)
    vv = v_ref[0, 0].astype(jnp.float32)
    ww = w_ref[0, 0].astype(jnp.float32)      # log-decay ≤ 0
    uu = u_ref[0].astype(jnp.float32)         # (1, hs) -> (hs,)

    L = jnp.cumsum(ww, axis=0)                # (C, hs), decreasing
    Lprev = L - ww
    Lend = L[-1:]                             # (1, hs)

    S = s_sc[...]
    # inter-chunk: o_t += (r_t ⊙ exp(Lprev_t)) @ S
    o_inter = jax.lax.dot_general(
        rr * jnp.exp(Lprev), S, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # intra-chunk (t > s): scores[t,s] = Σ_i r_t[i] k_s[i] exp(Lprev_t - L_s)
    dexp = jnp.exp(Lprev[:, None, :] - L[None, :, :])      # (C, C, hs) ≤ 1
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.sum(rr[:, None, :] * dexp * kk[None, :, :], axis=2)
    scores = jnp.where(tri, scores, 0.0)
    o_intra = jax.lax.dot_general(
        scores, vv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # bonus diagonal
    du = jnp.sum(rr * (uu * kk), axis=1, keepdims=True)    # (C,1)
    o_ref[0, 0] = (o_inter + o_intra + du * vv).astype(o_ref.dtype)
    # state update: S' = exp(Lend)ᵀ⊙S + Σ_s (k_s exp(Lend - L_s)) ⊗ v_s
    kdec = kk * jnp.exp(Lend - L)                          # (C, hs)
    s_sc[...] = jnp.exp(Lend)[0][:, None] * S + jax.lax.dot_general(
        kdec, vv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        s_out_ref[0, 0] = s_sc[...]


def wkv6_pallas(r, k, v, wlog, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,wlog: (B,S,H,hs); u: (H,hs). Returns (o (B,S,H,hs) f32,
    state (B,H,hs,hs) f32).  Initial state is zero (sequence start)."""
    b, s, h, hs = r.shape
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    # (B,H,S,hs) layout for blocking
    tr = lambda x: jnp.moveaxis(x, 1, 2)  # noqa: E731

    def xmap(bi, hi, ci):
        return (bi, hi, ci, 0)

    def umap(bi, hi, ci):
        return (hi, 0)

    def smap(bi, hi, ci):
        return (bi, hi, 0, 0)

    kern = functools.partial(_wkv_kernel, chunk=c, hs=hs, n_chunks=nc)
    o, s_out = pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[pl.BlockSpec((1, 1, c, hs), xmap)] * 4
        + [pl.BlockSpec((1, hs), umap)],
        out_specs=[pl.BlockSpec((1, 1, c, hs), xmap),
                   pl.BlockSpec((1, 1, hs, hs), smap)],
        out_shape=[jax.ShapeDtypeStruct((b, h, s, hs), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, hs, hs), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(wlog), u)
    return jnp.moveaxis(o, 2, 1), s_out
