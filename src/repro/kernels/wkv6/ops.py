"""Jit'd public wrapper for the wkv6 kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv_ref_chunked, wkv_ref_stepwise


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_op(r, k, v, wlog, u, *, chunk: int = 64, interpret: bool = True):
    return wkv6_pallas(r, k, v, wlog, u, chunk=chunk, interpret=interpret)
