"""Flash-attention forward as a Pallas TPU kernel.

Grid: (B·H, n_q_blocks, n_k_blocks) — the last axis iterates sequentially on
TPU, so the online-softmax statistics (m, l, acc) live in VMEM scratch and
persist across k-blocks.  Block shapes are MXU-aligned (multiples of 128 on
the sequence axes; head_dim ≤ 256 kept whole in VMEM).  Causal skipping is
block-level: k-blocks entirely above the diagonal are not computed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  bq: int, bk: int, causal: bool, scale: float,
                  n_k_blocks: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = qi * bq + (seq_k - seq_q)       # absolute q positions
    k_start = ki * bk
    run = (not causal) or True                # block reachability below

    @pl.when((not causal) or (k_start <= q_start + bq - 1))
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (bq, hd)
        k = k_ref[0].astype(jnp.float32)      # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _flush():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q,k,v: (B,H,S,hd) -> (B,H,Sq,hd)."""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    grid = (b * h, sq // bq, sk // bk)

    def qmap(bh, qi, ki):
        return (bh, qi, 0)

    def kmap(bh, qi, ki):
        return (bh, ki, 0)

    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, scale=hd ** -0.5,
        n_k_blocks=sk // bk, seq_q=sq, seq_k=sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), qmap),
            pl.BlockSpec((1, bk, hd), kmap),
            pl.BlockSpec((1, bk, hd), kmap),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * h, sq, hd), k.reshape(b * h, sk, hd),
      v.reshape(b * h, sk, hd))
    return out.reshape(b, h, sq, hd)
