"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B,H,S,hd) -> (B,H,S,hd); fp32 softmax."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.arange(sq)[:, None] + (sk - sq) >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
