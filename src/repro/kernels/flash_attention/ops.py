"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("causal",))
def attention_ref_op(q, k, v, *, causal: bool = True):
    return attention_ref(q, k, v, causal=causal)
