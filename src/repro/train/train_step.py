"""Train-step construction: value_and_grad + AdamW, ShardCtx-aware."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import factory
from repro.parallelism.ctx import NULL_CTX, ShardCtx
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def init_train_state(key, cfg: ArchConfig, opt_cfg: OptConfig,
                     dtype=jnp.float32, max_seq: int = 4096) -> dict:
    params = factory.init_params(key, cfg, dtype, max_seq=max_seq)
    return {
        "params": params,
        "opt": init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig,
                    ctx: ShardCtx = NULL_CTX, accum_steps: int = 1):
    """accum_steps > 1 scans over microbatches (leading-dim split of the
    global batch) accumulating fp32 grads before one optimizer update —
    trades step latency for activation memory, the standard lever when the
    per-device batch would not fit."""
    def grads_of(params, batch):
        def loss_fn(p):
            return factory.train_loss(p, batch, cfg=cfg, ctx=ctx)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(state["params"], batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                acc, _ = carry
                (loss, metrics), g = grads_of(state["params"], mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, metrics), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, metrics), _ = jax.lax.scan(
                body, (zeros, {"loss": jnp.zeros((), jnp.float32),
                               "ce": jnp.zeros((), jnp.float32),
                               "aux": jnp.zeros((), jnp.float32)}), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        new_p, new_opt, gnorm = adamw_update(
            grads, state["opt"], state["params"], opt_cfg, state["step"])
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, ctx: ShardCtx = NULL_CTX):
    def eval_step(params, batch):
        loss, metrics = factory.train_loss(params, batch, cfg=cfg, ctx=ctx)
        return metrics
    return eval_step
