"""In-house AdamW with global-norm clipping and warmup+cosine schedule.

Moments may be stored in bf16 ("compressed optimizer state" — used for the
two ≥400 B-parameter MoE architectures); the update maths always runs in
fp32.  Moments are ZeRO-1 sharded via parallelism/sharding.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # 'float32' | 'bfloat16'


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: OptConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay for norms / biases / 1-d params."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else ""
    return name not in ("scale", "bias", "mu_x", "mu", "mu_k", "mu_r",
                        "w0", "u", "gn_scale", "gn_bias", "dt_bias",
                        "conv_b", "D")


def adamw_update(grads, opt, params, cfg: OptConfig, step):
    """Returns (new_params, new_opt, gnorm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1.0)
    c2 = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1.0)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt["m"], opt["v"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v}, gnorm
