"""Batched design-space exploration: vmap the WHOLE simulator over configs.

The tentpole consequence of the static/dynamic config split (sim/config.py):
every timing parameter — scalar latencies AND the typed ``DynConfig``'s
per-class ``core.lat``/``core.disp`` tables — reaches the compiled engine as
a traced argument, so a sweep over N candidate configs that share one
``StaticConfig`` shape is a single ``jit(vmap(run_workload))`` — one XLA
program, one compilation, all lanes advancing together on one chip.  Each vmap lane is bit-identical to a
solo run of that config (tests/test_dse_sweep.py): JAX's while_loop batching
rule keeps finished lanes frozen via select, so early-finishing configs are
unaffected by stragglers.

With the trace-batching frontend (core/batch.py) the same trick applies to
the *workload* axis: whole workloads are padded + stacked into a leading
workload-lane axis, and ``grid_sweep(workloads, cfgs)`` runs the full
benchmarks × configs grid as ONE ``jit(vmap(vmap(run_workload_stacked)))``
program — every (workload, config) lane bit-identical to its solo run
(tests/test_zoo_grid.py; ``python -m repro.launch.zoo --grid 4 4 --check``).

Both sweeps optionally distribute over a 2-D ('cfg', 'sm') device mesh
(core/distribute.py): pass ``mesh=make_mesh(A, B)`` and the lane axis is
sharded over 'cfg' while each lane's SM axis is sharded over 'sm' — the
stacked dynamic-config pytree is placed with an explicit NamedSharding,
and every lane stays bit-identical to its solo run at any mesh shape
(tests/test_mesh_sweep.py).

PR 8 wins the batching bet — the monolithic grid ran at 0.62× a loop of
solo programs because every lane padded to the global max and rode the
longest lane's while_loop.  Three measures, all behind ``RunPlan``
(core/plan.py):

  · **bucketed lane packing** — ``plan.bucket_by='shape'|'cost'`` splits
    the workload lanes into ≤ ``plan.max_buckets`` buckets of similar
    padded shape / predicted cost (core/batch.py:bucket_workloads) and
    compiles one program per bucket, each padded only to ITS max;
  · **ragged layout** — ``plan.layout='ragged'`` concatenates each
    workload's kernels flat with an ``instr_base`` offset table instead of
    NOP-padding to the longest kernel (core/batch.py:concat_workloads);
  · **compile caching** — an in-process AOT executable cache
    (``timed_call(cache_key=...)``) plus jax's persistent compilation
    cache (``plan.cache_dir``) amortize the compile across sweeps and
    processes.

Every bucketed/ragged lane stays bit-identical to its solo run
(tests/test_bucketing.py); results come back in the original lane order
whatever the bucketing.

Usage:
    cfgs = [dataclasses.replace(TINY, l2_lat=v) for v in (16, 32, 64, ...)]
    result = sweep(workload, cfgs)
    result.stats  # list of per-config finalized stat dicts

    grid = grid_sweep([zoo_workload(n) for n in zoo_names()[:4]], cfgs)
    grid.stats[w][c]  # workload-major grid of finalized stat dicts

    plan = RunPlan(mesh=distribute.make_mesh(2, 2), bucket_by="cost")
    grid = grid_sweep(workloads, cfgs, plan=plan)   # same stats, sharded
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import stats as S
from repro.core import batch
from repro.core.batch import concat_workloads, stack_workloads
from repro.core.engine import run_workload_stacked
from repro.core.parallel import make_sm_runner
from repro.core.plan import RunPlan, resolve_plan
from repro.sim.config import StaticConfig, split_config
from repro.sim.state import init_state
from repro.sim.trace import Workload


def stack_dyn(cfgs):
    """Split each config and stack the typed ``DynConfig`` pytrees along a
    new leading lane axis — scalar leaves become ``(n,)``, the per-class
    ``core.lat``/``core.disp`` tables become ``(n, N_CLASSES)``.

    A lane may be a full ``GPUConfig`` or a pre-split ``(StaticConfig,
    dyn_overrides)`` pair (flat dict or ``DynConfig``) — the raw-table
    route a DSE search loop takes.  All lanes must share the same
    StaticConfig (one shape = one compiled program), and every lane is
    validated at build time, BEFORE any trace: split_config checks the
    override keys, the table lengths, and the machine invariant
    quantum Δ ≤ icnt_lat (config.py:check_dyn) — closing the flat-dict
    bypass of GPUConfig.__post_init__ — and any failure is re-raised
    naming the offending lane."""
    if not cfgs:
        raise ValueError("empty config list")
    splits = []
    for i, c in enumerate(cfgs):
        try:
            if isinstance(c, tuple) and len(c) == 2:
                splits.append(split_config(c[0], c[1]))
            else:
                splits.append(split_config(c))
        except ValueError as e:
            raise ValueError(f"config lane {i}: {e}") from None
    scfg = splits[0][0]
    for i, (s, _) in enumerate(splits):
        if s != scfg:
            raise ValueError(
                f"config {i} has a different static shape than config 0 "
                f"(vmap lanes must share one StaticConfig):\n  {s}\n  {scfg}")
    dyn_batch = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[d for _, d in splits])
    return scfg, dyn_batch


def batched_init(scfg: StaticConfig, *lanes: int) -> dict:
    """One ``init_state`` broadcast to the given leading lane axes —
    (n,) for a sweep, (W, C) for a grid.  Built OUTSIDE the compiled
    program so the runners can DONATE it (``donate_argnums=(0,)``): the
    output state aliases the input buffers and the quantum loop never
    holds two copies of the state in memory at once."""
    st = init_state(scfg)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, tuple(lanes) + x.shape).copy(), st)


def make_sweep_runner(scfg: StaticConfig, mode: str = "vmap",
                      max_cycles: int = 1 << 20, early_exit: bool = True,
                      donate: bool = True):
    """One compiled program: ``(state_batch, stacked_kernels, dyn_batch)
    -> final state batch``.  ``mode`` picks the SM-phase runner used
    inside every lane.

    The stacked kernel trace is an ARGUMENT (it used to be closed over),
    so one compiled executable serves every workload of the same stacked
    shape — the property the AOT compile cache keys on (``timed_call``).
    The initial state batch (``batched_init``) is an argument too, and
    DONATED by default: the final state aliases its buffers, halving the
    program's peak state footprint (benchmarks/packing.py probes this).
    A donated input is dead after the call — build a fresh state per
    invocation (``sweep`` does)."""
    sm_runner = make_sm_runner(scfg, mode)

    def run_one(state0, stacked, dyn):
        return run_workload_stacked(state0, stacked, scfg, dyn,
                                    sm_runner, max_cycles,
                                    early_exit=early_exit)

    return jax.jit(jax.vmap(run_one, in_axes=(0, None, 0)),
                   donate_argnums=(0,) if donate else ())


def take_lane(batched_state: dict, i: int) -> dict:
    """Slice lane ``i`` out of a batched final state."""
    return jax.tree_util.tree_map(lambda x: x[i], batched_state)


# in-process AOT executable cache: (program key, arg signature) -> compiled.
# Entries are XLA executables, reusable as long as the process lives; the
# cross-process analogue is jax's persistent compilation cache
# (core/plan.py:enable_persistent_cache).
_AOT_CACHE: dict = {}


def _arg_signature(args) -> tuple:
    """Shape/dtype/treedef fingerprint of a call's arguments — what an AOT
    executable is specialized on (beyond the program key)."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (str(treedef),
            tuple((tuple(getattr(x, "shape", ())),
                   str(getattr(x, "dtype", type(x).__name__)))
                  for x in leaves))


def aot_cache_key(scfg, plan: RunPlan, what: str) -> tuple:
    """Program identity for the AOT executable cache: everything that
    shapes the traced program besides the argument shapes — the hashable
    StaticConfig, the plan's execution knobs, and which runner (``what``:
    'sweep' | 'grid').  The mesh contributes its shape and device ids."""
    mesh_desc = None
    if plan.mesh is not None:
        mesh_desc = (tuple(plan.mesh.shape.items()),
                     tuple(d.id for d in plan.mesh.devices.flat))
    return (what, scfg, plan.mode, plan.exchange, plan.max_cycles,
            plan.early_exit, mesh_desc)


def clear_aot_cache() -> None:
    _AOT_CACHE.clear()


def timed_call(runner, *args, n_lanes: int = 1, cache_key=None) -> tuple:
    """Run a jitted program with the wall-clock split the run manifests
    record: AOT-lower + compile timed separately from execution, plus
    lanes/sec of the executed program.  Falls back to a plain (fused)
    call if AOT lowering is unavailable for the runner; the manifest then
    reports compile_s=None and the execute time includes compilation.

    With ``cache_key`` (``aot_cache_key``) the compiled executable is
    memoized on (key, argument shapes/dtypes): a warm call skips lower +
    compile entirely — ``timings['aot_cache']`` reports 'hit'/'miss'.
    Returns (result, timings)."""
    timings = {"n_lanes": n_lanes}
    fn = None
    if cache_key is not None:
        full_key = (cache_key, _arg_signature(args))
        fn = _AOT_CACHE.get(full_key)
        if fn is not None:
            timings["compile_s"] = 0.0
            timings["aot_cache"] = "hit"
    if fn is None:
        try:
            t0 = time.perf_counter()
            compiled = runner.lower(*args).compile()
            timings["compile_s"] = round(time.perf_counter() - t0, 4)
            fn = compiled
            if cache_key is not None:
                _AOT_CACHE[full_key] = compiled
                timings["aot_cache"] = "miss"
        except (AttributeError, TypeError, NotImplementedError):
            timings["compile_s"] = None
            fn = runner
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args))
    timings["execute_s"] = round(time.perf_counter() - t0, 4)
    timings["lanes_per_s"] = round(
        n_lanes / max(timings["execute_s"], 1e-9), 2)
    return out, timings


@dataclass
class SweepResult:
    scfg: StaticConfig
    state: dict                       # batched final state (leading lane axis)
    n: int
    stats: list = field(default_factory=list)   # per-lane finalized dicts
    timings: dict = field(default_factory=dict)  # compile/execute split

    @property
    def cycles(self):
        return [s["cycles"] for s in self.stats]

    def table(self, keys=("cycles", "ipc", "l1_miss", "l2_miss",
                          "dram_req")) -> list:
        return [{k: s[k] for k in keys} for s in self.stats]

    def timelines(self) -> dict:
        """{lane_index_str: (n_used, N_COUNTERS) sample rows} for every
        lane, when the StaticConfig enabled telemetry."""
        from repro.core import telemetry
        if not telemetry.enabled(self.scfg):
            return {}
        return {str(i): telemetry.timeline(take_lane(self.state, i))
                for i in range(self.n)}


def sweep(workload: Workload, cfgs, mode: str = None,
          max_cycles: int = None, mesh=None,
          exchange: str = None, plan: RunPlan = None) -> SweepResult:
    """Run ``workload`` under every config in one compiled, vmapped call.

    Execution knobs come from ``plan=`` (core/plan.py:RunPlan) — mesh
    distribution, trace layout, early-exit, compile caching.  The legacy
    flat kwargs (mode=/max_cycles=/mesh=/exchange=) still work for one
    release via the deprecation shim.  With a mesh, lanes are sharded
    over 'cfg' and each lane's SM axis over 'sm' — same stats, bit-exact,
    at any mesh shape."""
    plan = resolve_plan(plan, where="sweep", mode=mode,
                        max_cycles=max_cycles, mesh=mesh, exchange=exchange)
    plan.activate_caches()
    cfgs = plan.apply_telemetry(cfgs)
    scfg, dyn_batch = stack_dyn(cfgs)
    batch.check_workload_fits(scfg, workload)
    packs = [k.pack() for k in workload.kernels]
    stacked = (batch.concat_kernels(packs) if plan.layout == "ragged"
               else batch.stack_kernels(packs))
    key = aot_cache_key(scfg, plan, "sweep") if plan.aot_cache else None
    state0 = batched_init(scfg, len(cfgs))
    if plan.mesh is not None:
        from repro.core import distribute

        distribute.check_mesh(plan.mesh, scfg, len(cfgs))
        dyn_batch = distribute.place_lanes(dyn_batch, plan.mesh)
        stacked = distribute.place_lanes(
            stacked, plan.mesh, jax.sharding.PartitionSpec())
        state0 = distribute.place_state(state0, plan.mesh,
                                        distribute.CFG_AXIS)
        runner = distribute.make_dist_sweep_runner(
            scfg, plan.mesh, plan.max_cycles, plan.exchange,
            plan.early_exit)
    else:
        runner = make_sweep_runner(scfg, plan.mode, plan.max_cycles,
                                   plan.early_exit)
    bstate, timings = timed_call(runner, state0, stacked, dyn_batch,
                                 n_lanes=len(cfgs), cache_key=key)
    n = len(cfgs)
    stats = [S.finalize(take_lane(bstate, i)) for i in range(n)]
    return SweepResult(scfg=scfg, state=bstate, n=n, stats=stats,
                       timings=timings)


# ---------------------------------------------------------------------------
# grid sweep: benchmarks × configs in one compiled program
# ---------------------------------------------------------------------------

def make_grid_runner(scfg: StaticConfig, mode: str = "vmap",
                     max_cycles: int = 1 << 20, early_exit: bool = True,
                     donate: bool = True):
    """One compiled program for a whole (workload × config) grid:
    ``(state_grid, stacked_workloads, dyn_batch) -> final state`` with
    two leading lane axes (workload-major).  The inner vmap runs every
    config lane of one workload; the outer vmap runs every workload lane
    — all of it one XLA program, one dispatch per quantum for the entire
    grid.  The stacked trace may be padded or ragged (core/batch.py).
    The (W, C)-batched initial state (``batched_init``) is DONATED by
    default — final state aliases it, no second grid-state copy."""
    sm_runner = make_sm_runner(scfg, mode)

    def run_one(state0, stacked, dyn):
        return run_workload_stacked(state0, stacked, scfg, dyn,
                                    sm_runner, max_cycles,
                                    early_exit=early_exit)

    over_cfgs = jax.vmap(run_one, in_axes=(0, None, 0))
    return jax.jit(jax.vmap(over_cfgs, in_axes=(0, 0, None)),
                   donate_argnums=(0,) if donate else ())


def take_grid_lane(batched_state: dict, w: int, c: int) -> dict:
    """Slice lane (workload ``w``, config ``c``) out of a grid state."""
    return jax.tree_util.tree_map(lambda x: x[w, c], batched_state)


@dataclass
class GridResult:
    scfg: StaticConfig
    state: dict          # final state, leading (workload, config) lane axes
    names: list          # workload names, grid row order
    n_workloads: int
    n_cfgs: int
    stats: list = field(default_factory=list)   # stats[w][c] finalized dict
    timings: dict = field(default_factory=dict)  # compile/execute split
    # bucketed runs: [(workload_indices, bucket_state), ...] — each bucket
    # was its own compiled program; ``state`` is then the first bucket's
    # only if the grid was monolithic (single bucket), else None
    buckets: list = None

    def lane_state(self, w: int, c: int) -> dict:
        """Final state of lane (workload ``w``, config ``c``), whichever
        bucket it ran in."""
        if self.buckets is not None:
            for idxs, bstate in self.buckets:
                if w in idxs:
                    return take_grid_lane(bstate, idxs.index(w), c)
            raise KeyError(f"workload index {w} in no bucket")
        return take_grid_lane(self.state, w, c)

    def table(self, keys=("cycles", "ipc", "l1_miss", "l2_miss",
                          "dram_req")) -> list:
        return [{"workload": self.names[w], "cfg": c,
                 **{k: self.stats[w][c][k] for k in keys}}
                for w in range(self.n_workloads)
                for c in range(self.n_cfgs)]

    def timelines(self) -> dict:
        """{"<workload>/<cfg>": (n_used, N_COUNTERS) sample rows} per grid
        lane, when the StaticConfig enabled telemetry."""
        from repro.core import telemetry
        if not telemetry.enabled(self.scfg):
            return {}
        return {f"{self.names[w]}/{c}": telemetry.timeline(
                    self.lane_state(w, c))
                for w in range(self.n_workloads)
                for c in range(self.n_cfgs)}


def _run_grid_bucket(workloads, scfg, dyn_batch, plan: RunPlan,
                     n_cfgs: int):
    """One compiled grid program over a bucket of workloads: stack (or
    ragged-concat) the bucket's workloads — padded only to the BUCKET's
    max shape — and run all its (workload × config) lanes."""
    stacked = (concat_workloads(workloads) if plan.layout == "ragged"
               else stack_workloads(workloads))
    key = aot_cache_key(scfg, plan, "grid") if plan.aot_cache else None
    state0 = batched_init(scfg, len(workloads), n_cfgs)
    if plan.mesh is not None:
        from repro.core import distribute

        stacked = distribute.place_lanes(
            stacked, plan.mesh, jax.sharding.PartitionSpec())
        state0 = distribute.place_state(state0, plan.mesh, None,
                                        distribute.CFG_AXIS)
        runner = distribute.make_dist_grid_runner(
            scfg, plan.mesh, plan.max_cycles, plan.exchange,
            plan.early_exit)
    else:
        runner = make_grid_runner(scfg, plan.mode, plan.max_cycles,
                                  plan.early_exit)
    return timed_call(runner, state0, stacked, dyn_batch,
                      n_lanes=len(workloads) * n_cfgs, cache_key=key)


def bucket_groups(workloads, plan: RunPlan, scfg: StaticConfig) -> list:
    """The one bucket-forming policy ``grid_sweep`` and ``pair_sweep``
    share: partition the workload-lane indices per ``plan.bucket_by`` /
    ``plan.max_buckets`` (core/batch.py:bucket_workloads), seeding 'cost'
    keys from measured run-manifest hints refined by the analytic model
    when the bucket count is chosen automatically."""
    hints = None
    max_buckets = plan.max_buckets
    if plan.bucket_by == "cost":
        hints = batch.cost_hints_from_manifests()
        if max_buckets is None:
            # cost-model-driven bucket counts: lanes without a measured
            # manifest hint get an analytically-predicted cost key, and
            # bucket_workloads(max_buckets=None) minimizes the predicted
            # total padded cost over the candidate counts
            from repro.core import analytic
            hints = dict({w.name: analytic.predicted_workload_cost(w, scfg)
                          for w in workloads}, **hints)
    elif max_buckets is None:
        max_buckets = 4            # the classic ceiling for non-cost modes
    return batch.bucket_workloads(workloads, plan.bucket_by,
                                  max_buckets, hints)


def grid_sweep(workloads, cfgs, mode: str = None,
               max_cycles: int = None, mesh=None,
               exchange: str = None, plan: RunPlan = None) -> GridResult:
    """Simulate every workload under every config — W×C lanes, one
    compiled call per BUCKET.  Workloads are padded to a shared (kernel
    count, instruction count) with inert kernels/NOP slots (or
    ragged-concatenated, ``plan.layout``), so each lane is bit-identical
    to a solo ``simulate()`` of that (workload, config) pair.

    ``plan.bucket_by`` ('shape'/'cost') groups the workload lanes into
    ≤ ``plan.max_buckets`` buckets of similar padded shape / predicted
    cost (core/batch.py:bucket_workloads) and compiles one program per
    bucket — short lanes stop riding the longest lane's while_loop, which
    is what makes the batched grid beat a loop of solo programs
    (benchmarks/packing.py).  Stats come back in the original lane order.

    With a mesh (2-D ('cfg', 'sm'), core/distribute.py) config lanes are
    sharded over 'cfg', each lane's SM axis over 'sm'; the workload axis
    is replicated.  Stats are bit-exact at any mesh shape."""
    plan = resolve_plan(plan, where="grid_sweep", mode=mode,
                        max_cycles=max_cycles, mesh=mesh, exchange=exchange)
    plan.activate_caches()
    cfgs = plan.apply_telemetry(cfgs)
    scfg, dyn_batch = stack_dyn(cfgs)
    for w in workloads:
        batch.check_workload_fits(scfg, w)
    if plan.mesh is not None:
        from repro.core import distribute

        distribute.check_mesh(plan.mesh, scfg, len(cfgs))
        dyn_batch = distribute.place_lanes(dyn_batch, plan.mesh)

    nw, nc = len(workloads), len(cfgs)
    groups = bucket_groups(workloads, plan, scfg)

    stats = [[None] * nc for _ in range(nw)]
    bucket_states = []
    timings = {"n_lanes": nw * nc, "n_buckets": len(groups),
               "compile_s": 0.0, "execute_s": 0.0}
    for idxs in groups:
        bstate, tm = _run_grid_bucket([workloads[i] for i in idxs], scfg,
                                      dyn_batch, plan, nc)
        bucket_states.append((list(idxs), bstate))
        for pos, w in enumerate(idxs):
            for c in range(nc):
                stats[w][c] = S.finalize(take_grid_lane(bstate, pos, c))
        if tm.get("compile_s") is None or timings["compile_s"] is None:
            timings["compile_s"] = None
        else:
            timings["compile_s"] = round(
                timings["compile_s"] + tm["compile_s"], 4)
        timings["execute_s"] = round(
            timings["execute_s"] + tm["execute_s"], 4)
        if "aot_cache" in tm:
            timings["aot_cache"] = tm["aot_cache"] if \
                timings.get("aot_cache") in (None, tm["aot_cache"]) \
                else "mixed"
    timings["lanes_per_s"] = round(
        nw * nc / max(timings["execute_s"], 1e-9), 2)
    single = bucket_states[0][1] if len(groups) == 1 else None
    return GridResult(scfg=scfg, state=single,
                      names=[w.name for w in workloads],
                      n_workloads=nw, n_cfgs=nc, stats=stats,
                      timings=timings, buckets=bucket_states)


# ---------------------------------------------------------------------------
# pair sweep: heterogeneous (workload, config) lanes — the serving batcher
# ---------------------------------------------------------------------------

def make_pair_runner(scfg: StaticConfig, mode: str = "vmap",
                     max_cycles: int = 1 << 20, early_exit: bool = True,
                     donate: bool = True):
    """One compiled program over a batch of *pair* lanes: every lane
    carries its OWN workload and its OWN dynamic config —
    ``(state_batch, stacked_workloads, dyn_batch) -> final state batch``
    with all three arguments vmapped along the lane axis
    (``in_axes=(0, 0, 0)``), unlike the grid runner's workload × config
    cross product.  This is the shape a simulation server's continuous
    batcher needs (core/service.py): N unrelated submissions — different
    benchmarks, different timing points — advance together as N lanes of
    one XLA program.  The (n,)-batched initial state is DONATED."""
    sm_runner = make_sm_runner(scfg, mode)

    def run_one(state0, stacked, dyn):
        return run_workload_stacked(state0, stacked, scfg, dyn,
                                    sm_runner, max_cycles,
                                    early_exit=early_exit)

    return jax.jit(jax.vmap(run_one, in_axes=(0, 0, 0)),
                   donate_argnums=(0,) if donate else ())


@dataclass
class PairResult:
    """Result of a ``pair_sweep``: per-lane finalized stats in submission
    order, whatever the bucketing, plus the per-bucket final states."""
    scfg: StaticConfig
    n: int
    stats: list = field(default_factory=list)    # per-lane finalized dicts
    timings: dict = field(default_factory=dict)  # compile/execute split
    # [(lane_indices, bucket_state), ...] — lane i's state sits at
    # position lane_indices.index(i) of its bucket (duplicate fill lanes
    # past len(lane_indices) are discarded)
    buckets: list = field(default_factory=list)

    def lane_state(self, i: int) -> dict:
        for idxs, bstate in self.buckets:
            if i in idxs:
                return take_lane(bstate, idxs.index(i))
        raise KeyError(f"lane index {i} in no bucket")


def _pad_fill(idxs: list, lane_quantum: int | None) -> list:
    """Round a bucket's lane list up to a multiple of ``lane_quantum`` by
    repeating its own lanes cyclically — padded slots carry LIVE work
    (a duplicate of a real lane is bit-identical and independent under
    vmap) instead of inert NOPs, and the rounded lane counts keep the
    AOT executable cache hot across batches of drifting size."""
    if not lane_quantum or lane_quantum <= 1:
        return list(idxs)
    n = len(idxs)
    padded = ((n + lane_quantum - 1) // lane_quantum) * lane_quantum
    return [idxs[j % n] for j in range(padded)]


def pair_sweep(pairs, plan: RunPlan = None,
               lane_quantum: int | None = None) -> PairResult:
    """Run a heterogeneous batch of (workload, config) PAIR lanes — lane
    ``i`` simulates ``pairs[i] = (workload_i, cfg_i)`` — in one compiled
    vmapped program per bucket.  This is the execution primitive behind
    the simulation server (core/service.py): unlike ``grid_sweep``'s
    cross product, every lane is an independent submission, so unrelated
    jobs co-batch whenever their workloads share a padded footprint
    bucket (``plan.bucket_by``, core/batch.py:bucket_workloads).

    Every lane is bit-identical to a solo ``simulate(workload, cfg)`` of
    its pair regardless of which strangers it was batched with, the
    arrival order, or the batch boundaries (tests/test_service.py) — the
    vmap/padding machinery is exactly the grid's, which
    tests/test_zoo_grid.py pins against solo runs.

    ``lane_quantum`` rounds each bucket's lane count up to a multiple by
    repeating live lanes (``_pad_fill``); duplicate results are dropped.
    All configs must share one StaticConfig; the mesh path is not wired
    for pair lanes (use grid_sweep for mesh runs)."""
    plan = resolve_plan(plan, where="pair_sweep")
    if plan.mesh is not None:
        raise ValueError("pair_sweep does not support mesh distribution; "
                         "use grid_sweep for mesh runs")
    if not pairs:
        raise ValueError("empty pair list")
    plan.activate_caches()
    workloads = [w for w, _ in pairs]
    cfgs = plan.apply_telemetry([c for _, c in pairs])
    scfg, _ = stack_dyn(cfgs)          # validates the shared static shape
    for w in workloads:
        batch.check_workload_fits(scfg, w)
    groups = bucket_groups(workloads, plan, scfg)

    n = len(pairs)
    stats = [None] * n
    bucket_states = []
    timings = {"n_lanes": n, "n_buckets": len(groups),
               "compile_s": 0.0, "execute_s": 0.0}
    key = aot_cache_key(scfg, plan, "pair") if plan.aot_cache else None
    for idxs in groups:
        fill = _pad_fill(idxs, lane_quantum)
        ws = [workloads[i] for i in fill]
        stacked = (concat_workloads(ws) if plan.layout == "ragged"
                   else stack_workloads(ws))
        _, dyn_b = stack_dyn([cfgs[i] for i in fill])
        state0 = batched_init(scfg, len(fill))
        runner = make_pair_runner(scfg, plan.mode, plan.max_cycles,
                                  plan.early_exit)
        bstate, tm = timed_call(runner, state0, stacked, dyn_b,
                                n_lanes=len(idxs), cache_key=key)
        bucket_states.append((list(idxs), bstate))
        for pos, i in enumerate(idxs):      # duplicates past len(idxs) drop
            stats[i] = S.finalize(take_lane(bstate, pos))
        if tm.get("compile_s") is None or timings["compile_s"] is None:
            timings["compile_s"] = None
        else:
            timings["compile_s"] = round(
                timings["compile_s"] + tm["compile_s"], 4)
        timings["execute_s"] = round(
            timings["execute_s"] + tm["execute_s"], 4)
        if "aot_cache" in tm:
            timings["aot_cache"] = tm["aot_cache"] if \
                timings.get("aot_cache") in (None, tm["aot_cache"]) \
                else "mixed"
    timings["lanes_per_s"] = round(n / max(timings["execute_s"], 1e-9), 2)
    return PairResult(scfg=scfg, n=n, stats=stats, timings=timings,
                      buckets=bucket_states)
