"""Batched design-space exploration: vmap the WHOLE simulator over configs.

The tentpole consequence of the static/dynamic config split (sim/config.py):
every timing parameter — scalar latencies AND the typed ``DynConfig``'s
per-class ``core.lat``/``core.disp`` tables — reaches the compiled engine as
a traced argument, so a sweep over N candidate configs that share one
``StaticConfig`` shape is a single ``jit(vmap(run_workload))`` — one XLA
program, one compilation, all lanes advancing together on one chip.  Each vmap lane is bit-identical to a
solo run of that config (tests/test_dse_sweep.py): JAX's while_loop batching
rule keeps finished lanes frozen via select, so early-finishing configs are
unaffected by stragglers.

With the trace-batching frontend (core/batch.py) the same trick applies to
the *workload* axis: whole workloads are padded + stacked into a leading
workload-lane axis, and ``grid_sweep(workloads, cfgs)`` runs the full
benchmarks × configs grid as ONE ``jit(vmap(vmap(run_workload_stacked)))``
program — every (workload, config) lane bit-identical to its solo run
(tests/test_zoo_grid.py; ``python -m repro.launch.zoo --grid 4 4 --check``).

Both sweeps optionally distribute over a 2-D ('cfg', 'sm') device mesh
(core/distribute.py): pass ``mesh=make_mesh(A, B)`` and the lane axis is
sharded over 'cfg' while each lane's SM axis is sharded over 'sm' — the
stacked dynamic-config pytree is placed with an explicit NamedSharding,
and every lane stays bit-identical to its solo run at any mesh shape
(tests/test_mesh_sweep.py).

Usage:
    cfgs = [dataclasses.replace(TINY, l2_lat=v) for v in (16, 32, 64, ...)]
    result = sweep(workload, cfgs)
    result.stats  # list of per-config finalized stat dicts

    grid = grid_sweep([zoo_workload(n) for n in zoo_names()[:4]], cfgs)
    grid.stats[w][c]  # workload-major grid of finalized stat dicts

    mesh = distribute.make_mesh(2, 2)          # 4 devices, ('cfg', 'sm')
    grid = grid_sweep(workloads, cfgs, mesh=mesh)   # same stats, sharded
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import stats as S
from repro.core import batch
from repro.core.batch import stack_workloads
from repro.core.engine import run_workload, run_workload_stacked
from repro.core.parallel import make_sm_runner
from repro.sim.config import StaticConfig, split_config
from repro.sim.state import init_state
from repro.sim.trace import Workload


def stack_dyn(cfgs):
    """Split each config and stack the typed ``DynConfig`` pytrees along a
    new leading lane axis — scalar leaves become ``(n,)``, the per-class
    ``core.lat``/``core.disp`` tables become ``(n, N_CLASSES)``.

    A lane may be a full ``GPUConfig`` or a pre-split ``(StaticConfig,
    dyn_overrides)`` pair (flat dict or ``DynConfig``) — the raw-table
    route a DSE search loop takes.  All lanes must share the same
    StaticConfig (one shape = one compiled program), and every lane is
    validated at build time, BEFORE any trace: split_config checks the
    override keys, the table lengths, and the machine invariant
    quantum Δ ≤ icnt_lat (config.py:check_dyn) — closing the flat-dict
    bypass of GPUConfig.__post_init__ — and any failure is re-raised
    naming the offending lane."""
    if not cfgs:
        raise ValueError("empty config list")
    splits = []
    for i, c in enumerate(cfgs):
        try:
            if isinstance(c, tuple) and len(c) == 2:
                splits.append(split_config(c[0], c[1]))
            else:
                splits.append(split_config(c))
        except ValueError as e:
            raise ValueError(f"config lane {i}: {e}") from None
    scfg = splits[0][0]
    for i, (s, _) in enumerate(splits):
        if s != scfg:
            raise ValueError(
                f"config {i} has a different static shape than config 0 "
                f"(vmap lanes must share one StaticConfig):\n  {s}\n  {scfg}")
    dyn_batch = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[d for _, d in splits])
    return scfg, dyn_batch


def make_sweep_runner(scfg: StaticConfig, packed_kernels: list,
                      mode: str = "vmap", max_cycles: int = 1 << 20):
    """One compiled program: dyn_batch (lane-stacked pytree) -> final state
    batch.  ``mode`` picks the SM-phase runner used inside every lane."""
    sm_runner = make_sm_runner(scfg, mode)

    def run_one(dyn):
        state = init_state(scfg)
        return run_workload(state, packed_kernels, scfg, dyn, sm_runner,
                            max_cycles)

    return jax.jit(jax.vmap(run_one))


def take_lane(batched_state: dict, i: int) -> dict:
    """Slice lane ``i`` out of a batched final state."""
    return jax.tree_util.tree_map(lambda x: x[i], batched_state)


def timed_call(runner, *args, n_lanes: int = 1) -> tuple:
    """Run a jitted program with the wall-clock split the run manifests
    record: AOT-lower + compile timed separately from execution, plus
    lanes/sec of the executed program.  Falls back to a plain (fused)
    call if AOT lowering is unavailable for the runner; the manifest then
    reports compile_s=None and the execute time includes compilation.
    Returns (result, timings)."""
    timings = {"n_lanes": n_lanes}
    try:
        t0 = time.perf_counter()
        compiled = runner.lower(*args).compile()
        timings["compile_s"] = round(time.perf_counter() - t0, 4)
        fn = compiled
    except (AttributeError, TypeError, NotImplementedError):
        timings["compile_s"] = None
        fn = runner
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args))
    timings["execute_s"] = round(time.perf_counter() - t0, 4)
    timings["lanes_per_s"] = round(
        n_lanes / max(timings["execute_s"], 1e-9), 2)
    return out, timings


@dataclass
class SweepResult:
    scfg: StaticConfig
    state: dict                       # batched final state (leading lane axis)
    n: int
    stats: list = field(default_factory=list)   # per-lane finalized dicts
    timings: dict = field(default_factory=dict)  # compile/execute split

    @property
    def cycles(self):
        return [s["cycles"] for s in self.stats]

    def table(self, keys=("cycles", "ipc", "l1_miss", "l2_miss",
                          "dram_req")) -> list:
        return [{k: s[k] for k in keys} for s in self.stats]

    def timelines(self) -> dict:
        """{lane_index_str: (n_used, N_COUNTERS) sample rows} for every
        lane, when the StaticConfig enabled telemetry."""
        from repro.core import telemetry
        if not telemetry.enabled(self.scfg):
            return {}
        return {str(i): telemetry.timeline(take_lane(self.state, i))
                for i in range(self.n)}


def sweep(workload: Workload, cfgs, mode: str = "vmap",
          max_cycles: int = 1 << 20, mesh=None,
          exchange: str = "window") -> SweepResult:
    """Run ``workload`` under every config in one compiled, vmapped call.

    With ``mesh`` (a 2-D ('cfg', 'sm') Mesh, core/distribute.py:make_mesh)
    the lanes are sharded over the 'cfg' axis and each lane's SM axis over
    'sm' — same stats, bit-exact, at any mesh shape."""
    scfg, dyn_batch = stack_dyn(cfgs)
    batch.check_workload_fits(scfg, workload)
    packed = [k.pack() for k in workload.kernels]
    if mesh is not None:
        from repro.core import distribute
        from repro.core.batch import stack_kernels

        if mode != "vmap":
            raise ValueError(
                f"mode={mode!r} conflicts with mesh=: the distributed "
                "path has its own in-lane execution (sharded SM axis); "
                "pass mode='vmap' (the default) or drop mesh=")
        distribute.check_mesh(mesh, scfg, len(cfgs))
        dyn_batch = distribute.place_lanes(dyn_batch, mesh)
        runner = distribute.make_dist_sweep_runner(scfg, mesh, max_cycles,
                                                   exchange)
        bstate, timings = timed_call(runner, stack_kernels(packed),
                                     dyn_batch, n_lanes=len(cfgs))
    else:
        runner = make_sweep_runner(scfg, packed, mode, max_cycles)
        bstate, timings = timed_call(runner, dyn_batch, n_lanes=len(cfgs))
    n = len(cfgs)
    stats = [S.finalize(take_lane(bstate, i)) for i in range(n)]
    return SweepResult(scfg=scfg, state=bstate, n=n, stats=stats,
                       timings=timings)


# ---------------------------------------------------------------------------
# grid sweep: benchmarks × configs in one compiled program
# ---------------------------------------------------------------------------

def make_grid_runner(scfg: StaticConfig, mode: str = "vmap",
                     max_cycles: int = 1 << 20):
    """One compiled program for a whole (workload × config) grid:
    ``(stacked_workloads, dyn_batch) -> final state`` with two leading
    lane axes (workload-major).  The inner vmap runs every config lane of
    one workload; the outer vmap runs every workload lane — all of it one
    XLA program, one dispatch per quantum for the entire grid."""
    sm_runner = make_sm_runner(scfg, mode)

    def run_one(stacked, dyn):
        return run_workload_stacked(init_state(scfg), stacked, scfg, dyn,
                                    sm_runner, max_cycles)

    over_cfgs = jax.vmap(run_one, in_axes=(None, 0))
    return jax.jit(jax.vmap(over_cfgs, in_axes=(0, None)))


def take_grid_lane(batched_state: dict, w: int, c: int) -> dict:
    """Slice lane (workload ``w``, config ``c``) out of a grid state."""
    return jax.tree_util.tree_map(lambda x: x[w, c], batched_state)


@dataclass
class GridResult:
    scfg: StaticConfig
    state: dict          # final state, leading (workload, config) lane axes
    names: list          # workload names, grid row order
    n_workloads: int
    n_cfgs: int
    stats: list = field(default_factory=list)   # stats[w][c] finalized dict
    timings: dict = field(default_factory=dict)  # compile/execute split

    def table(self, keys=("cycles", "ipc", "l1_miss", "l2_miss",
                          "dram_req")) -> list:
        return [{"workload": self.names[w], "cfg": c,
                 **{k: self.stats[w][c][k] for k in keys}}
                for w in range(self.n_workloads)
                for c in range(self.n_cfgs)]

    def timelines(self) -> dict:
        """{"<workload>/<cfg>": (n_used, N_COUNTERS) sample rows} per grid
        lane, when the StaticConfig enabled telemetry."""
        from repro.core import telemetry
        if not telemetry.enabled(self.scfg):
            return {}
        return {f"{self.names[w]}/{c}": telemetry.timeline(
                    take_grid_lane(self.state, w, c))
                for w in range(self.n_workloads)
                for c in range(self.n_cfgs)}


def grid_sweep(workloads, cfgs, mode: str = "vmap",
               max_cycles: int = 1 << 20, mesh=None,
               exchange: str = "window") -> GridResult:
    """Simulate every workload under every config — W×C lanes, ONE
    compiled call.  Workloads are padded to shared (kernel count,
    instruction count) with inert kernels/NOP slots (core/batch.py), so
    each lane is bit-identical to a solo ``simulate()`` of that
    (workload, config) pair.

    With ``mesh`` (2-D ('cfg', 'sm'), core/distribute.py) config lanes
    are sharded over 'cfg', each lane's SM axis over 'sm'; the workload
    axis is replicated.  Stats are bit-exact at any mesh shape."""
    scfg, dyn_batch = stack_dyn(cfgs)
    for w in workloads:
        batch.check_workload_fits(scfg, w)
    stacked = stack_workloads(workloads)
    if mesh is not None:
        from repro.core import distribute

        if mode != "vmap":
            raise ValueError(
                f"mode={mode!r} conflicts with mesh=: the distributed "
                "path has its own in-lane execution (sharded SM axis); "
                "pass mode='vmap' (the default) or drop mesh=")
        distribute.check_mesh(mesh, scfg, len(cfgs))
        dyn_batch = distribute.place_lanes(dyn_batch, mesh)
        stacked = distribute.place_lanes(
            stacked, mesh, jax.sharding.PartitionSpec())
        runner = distribute.make_dist_grid_runner(scfg, mesh, max_cycles,
                                                  exchange)
    else:
        runner = make_grid_runner(scfg, mode, max_cycles)
    nw, nc = len(workloads), len(cfgs)
    bstate, timings = timed_call(runner, stacked, dyn_batch,
                                 n_lanes=nw * nc)
    stats = [[S.finalize(take_grid_lane(bstate, w, c)) for c in range(nc)]
             for w in range(nw)]
    return GridResult(scfg=scfg, state=bstate,
                      names=[w.name for w in workloads],
                      n_workloads=nw, n_cfgs=nc, stats=stats,
                      timings=timings)
