"""Cycle-resolved counter timelines + run-manifest telemetry.

Two halves, one module:

**In-trace timelines** — when ``StaticConfig.telemetry_samples > 0`` the
state pytree (sim/state.py:init_state) grows a ``telem`` part: a
preallocated ``(telemetry_samples, N_COUNTERS)`` int32 ring-free buffer, a
write index, and a cumulative *lockstep-waste* accumulator.  Every
``telemetry_every``-th quantum the engine snapshots the cumulative per-SM
counters (summed over SMs), the global memory-system counters, the
instantaneous live-warp count and the waste accumulator into the next
buffer row (``sample``); the end of every kernel forces a snapshot, so the
LAST written row always equals the run's final cumulative counters —
the invariant tests/test_telemetry.py locks against ``stats.finalize``.
Lockstep waste counts, per quantum, Δ cycles for every SM that sits fully
converged (no live warps, no in-flight memory requests) while the kernel
as a whole is still running — the cycles the lockstep ``while_loop`` burns
riding the longest SM/lane, the suspected cause of the batched-grid
regression in ROADMAP's top open item.

The buffer lives INSIDE the traced program, so timelines ride every
execution path unchanged: vmapped config lanes (core/sweep.py) carry a
leading lane axis, grid sweeps two, and under the 2-D ('cfg', 'sm') mesh
(core/distribute.py) the counter reductions ``psum`` over the 'sm' axis so
the replicated buffer holds full-machine totals.  With telemetry disabled
(the default) the state pytree and the compiled program are bit-for-bit
unchanged — the determinism golden needs no regeneration.

**Run manifests** — every launcher/bench run can write a structured JSON
manifest under ``experiments/runs/``: git sha, StaticConfig hash, host
context (hostname, device kind/count, XLA_FLAGS), mesh shape, the
compile-vs-execute wall-clock split and lanes/sec of the compiled
program, final per-lane stats, and the sampled timelines.
``launch/report.py`` renders/diffs them.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# counter layout
# ---------------------------------------------------------------------------

# cumulative per-SM counters (sim/state.py "stats_sm"), summed over SMs at
# sample time — each matches the identically-named stats.finalize total
CUM_SM = ("issued", "issued_mem", "l1_hit", "l1_miss", "cycles_issue",
          "stall", "warp_cycles")
# cumulative global counters (serial-region "stats")
CUM_GLOBAL = ("l2_hit", "l2_miss", "dram_req", "dram_row_hit",
              "ctas_launched")
# gauges: instantaneous / telemetry-only values
GAUGES = ("active_warps", "lockstep_waste")
COUNTERS = ("cycle",) + CUM_SM + CUM_GLOBAL + GAUGES
N_COUNTERS = len(COUNTERS)
# the columns that must equal stats.finalize totals in the final sample
FINAL_MATCH = CUM_SM + CUM_GLOBAL


def enabled(scfg) -> bool:
    """Static (Python-level) gate: telemetry changes the state pytree and
    the compiled program ONLY when the StaticConfig asks for samples."""
    return getattr(scfg, "telemetry_samples", 0) > 0


def init(scfg) -> dict:
    """The ``telem`` state part: preallocated sample buffer + write index
    + cumulative lockstep-waste accumulator.  Shapes depend only on the
    telemetry knobs, so the part is replicated under 'sm' sharding and
    vmaps over config/workload lanes like any other state."""
    return {
        "buf": jnp.zeros((scfg.telemetry_samples, N_COUNTERS), jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
        "waste": jnp.zeros((), jnp.int32),
    }


def _tot(x, axis_name):
    """Sum a (possibly device-local) per-SM array to a full-machine total:
    local sum, then psum over the mesh axis when sharded."""
    s = jnp.sum(x, dtype=jnp.int32)
    return jax.lax.psum(s, axis_name) if axis_name else s


def _row(telem: dict, state: dict, axis_name=None):
    """One (N_COUNTERS,) snapshot of the current cumulative counters."""
    vals = [state["ctrl"]["cycle"]]
    vals += [_tot(state["stats_sm"][k], axis_name) for k in CUM_SM]
    vals += [jnp.asarray(state["stats"][k], jnp.int32) for k in CUM_GLOBAL]
    vals.append(_tot(state["warp"]["active"], axis_name))
    vals.append(telem["waste"])
    return jnp.stack(vals)


def waste_increment(state: dict, n_instr, scfg, axis_name=None):
    """Lockstep waste accrued this quantum: Δ cycles for every SM with no
    live warps AND no in-flight memory requests (fully converged — nothing
    can wake it but the quantum barrier) while the kernel is not done."""
    warp = state["warp"]
    live = warp["active"] & ~((warp["pc"] >= n_instr)
                              & (warp["pending"] == 0))
    sm_live = jnp.any(live, axis=1)                       # (n_sm_local,)
    sm_busy = jnp.any(state["req"]["stage"] != 0, axis=1)
    idle = jnp.sum(~sm_live & ~sm_busy, dtype=jnp.int32)
    if axis_name:
        idle = jax.lax.psum(idle, axis_name)
    running = state["ctrl"]["done_cycle"] < 0
    return jnp.where(running, idle * scfg.quantum, 0)


def sample(telem: dict, state: dict, scfg, axis_name=None,
           force: bool = False) -> dict:
    """Maybe write a snapshot row.  Periodic samples fire every
    ``telemetry_every``-th quantum while the buffer has room; ``force``
    (end of kernel) always writes, overwriting the last slot when full —
    so the final written row is always the final cumulative counters."""
    n = scfg.telemetry_samples
    if force:
        do = jnp.ones((), jnp.bool_)
    else:
        q = state["ctrl"]["cycle"] // scfg.quantum
        do = (q % scfg.telemetry_every == 0) & (telem["idx"] < n)
    row = _row(telem, state, axis_name)
    pos = jnp.clip(telem["idx"], 0, n - 1)
    buf = telem["buf"].at[pos].set(
        jnp.where(do, row, telem["buf"][pos]))
    idx = jnp.minimum(telem["idx"] + jnp.where(do, 1, 0), n)
    return dict(telem, buf=buf, idx=idx)


def quantum_update(telem: dict, state: dict, trace: dict, scfg,
                   axis_name=None) -> dict:
    """Per-quantum telemetry step, called at the end of every quantum body
    (engine.quantum_step / the distributed kernel runners): accumulate
    lockstep waste, then take a periodic sample."""
    telem = dict(telem, waste=telem["waste"] + waste_increment(
        state, trace["n_instr"], scfg, axis_name))
    return sample(telem, state, scfg, axis_name)


# ---------------------------------------------------------------------------
# host-side extraction
# ---------------------------------------------------------------------------

def timeline(state: dict) -> np.ndarray:
    """The used rows of one lane's sample buffer as an (n_used, N_COUNTERS)
    numpy array (lane-sliced state: take_lane / take_grid_lane)."""
    telem = state["telem"]
    idx = int(np.asarray(telem["idx"]))
    return np.asarray(telem["buf"])[:idx]


def check_final_sample(state: dict, finalized: dict) -> list:
    """Names of FINAL_MATCH counters whose last timeline sample does NOT
    equal the finalize() total — empty list means the invariant holds."""
    tl = timeline(state)
    if tl.shape[0] == 0:
        return ["<no samples>"]
    last = tl[-1]
    return [name for name in FINAL_MATCH
            if int(last[COUNTERS.index(name)]) != int(finalized[name])]


# ---------------------------------------------------------------------------
# run manifests
# ---------------------------------------------------------------------------

MANIFEST_SCHEMA = 1


def runs_dir() -> str:
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(here, "experiments", "runs")


def git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        import subprocess
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True,
                text=True, timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            sha = ""
    return sha or "unknown"


def static_hash(scfg) -> str:
    """Stable short hash of a StaticConfig — manifests from the same shape
    (hence the same compiled-program cache key) share it."""
    payload = json.dumps(asdict(scfg), sort_keys=True)
    return hashlib.sha1(payload.encode()).hexdigest()[:12]


def host_context() -> dict:
    """Where a run happened — hostname, device kind/count, the XLA flags
    that shape compilation.  Cross-machine BENCH/manifest comparisons are
    meaningless without this label."""
    import platform
    import socket

    ctx = {
        "hostname": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
    }
    try:
        devs = jax.devices()
        ctx["jax_version"] = jax.__version__
        ctx["device_platform"] = devs[0].platform
        ctx["device_kind"] = devs[0].device_kind
        ctx["device_count"] = len(devs)
    except Exception:  # noqa: BLE001 — jax may be unusable in odd envs
        ctx["device_platform"] = "unknown"
    return ctx


def write_manifest(kind: str, *, scfg=None, mesh_shape=None, timings=None,
                   stats=None, timelines=None, lanes=None, extra=None,
                   out_dir=None) -> str:
    """Write one structured run manifest JSON under experiments/runs/.

    ``stats``: list of finalized per-lane stat dicts (made JSON-safe via
    stats.to_jsonable).  ``timelines``: {lane_key: [[row], ...]} sampled
    counter timelines (column order = COUNTERS).  ``lanes``: per-lane
    descriptions (config knobs / workload names).  Returns the path.
    """
    from repro.core.stats import to_jsonable

    out_dir = out_dir or runs_dir()
    os.makedirs(out_dir, exist_ok=True)
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    path = os.path.join(out_dir, f"{stamp}_{kind.replace('/', '_')}.json")
    # never silently overwrite a same-second manifest
    seq = 1
    while os.path.exists(path):
        path = os.path.join(out_dir,
                            f"{stamp}_{kind.replace('/', '_')}.{seq}.json")
        seq += 1
    payload = {
        "schema": MANIFEST_SCHEMA,
        "kind": kind,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": git_sha(),
        "host": host_context(),
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "timings": to_jsonable(timings or {}),
    }
    if scfg is not None:
        payload["static_config"] = to_jsonable(asdict(scfg))
        payload["static_config_hash"] = static_hash(scfg)
        payload["telemetry"] = {
            "samples": getattr(scfg, "telemetry_samples", 0),
            "every": getattr(scfg, "telemetry_every", 1),
            "counters": list(COUNTERS),
        }
    if lanes is not None:
        payload["lanes"] = to_jsonable(lanes)
    if stats is not None:
        payload["stats"] = to_jsonable(stats)
    if timelines is not None:
        payload["timelines"] = to_jsonable(timelines)
    if extra:
        payload.update(to_jsonable(extra))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def write_job_manifest(job, *, scfg=None, out_dir=None) -> str:
    """Per-job manifest for the sim server (core/service.py): the job's
    identity, its per-lane ``finalize`` stats, and the latency split the
    serving story is about — how long the job queued vs how long its
    batch spent compiling vs executing.  Same schema/venue as every
    other run manifest (experiments/runs/), so report.py and
    cost_hints_from_manifests see served jobs like any other run."""
    return write_manifest(
        "serve_job", scfg=scfg, stats=job.stats,
        timings=dict(job.latency(), **{
            "n_lanes": job.n_lanes,
            "batch_lanes": (job.batch or {}).get("n_lanes"),
            "aot_cache": (job.batch or {}).get("aot_cache"),
        }),
        lanes=[{"workload": job.name}] * job.n_lanes,
        extra={"job": {"id": job.id, "seq": job.seq,
                       "batch": job.batch}},
        out_dir=out_dir)
