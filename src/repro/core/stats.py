"""Deterministic stat reduction — the paper's epilogue gather.

Per-SM counters are integers, so the reduction is bit-exact regardless of
execution mode or device count.  The per-SM bounded address sets (paper's
set-valued stat, strategy 2) are unioned here, on the host, once.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def finalize(state: dict) -> dict:
    out = {}
    for k, v in state["stats_sm"].items():
        arr = np.asarray(v).astype(np.int64)
        out[k] = int(arr.sum())
        out[f"{k}_per_sm"] = arr
    for k, v in state["stats"].items():
        out[k] = int(v)
    out["cycles"] = int(state["ctrl"].get("total_cycles",
                                          state["ctrl"]["cycle"]))
    # truncation accounting: kernels that hit max_cycles before finishing
    # (engine.run_workload* count them; done_cycle stayed negative).  Kept
    # out of comparable() — it is run-harness metadata, not timing state.
    out["timeouts"] = int(state["ctrl"].get("timeouts", 0))
    out["timeout"] = out["timeouts"] > 0
    # set-valued stat: union of per-SM address sets
    aset = np.asarray(state["sm"]["addrset"]).ravel()
    out["unique_addrs"] = int(np.unique(aset[aset >= 0]).size)
    out["addrset_overflow"] = int(np.sum(
        np.asarray(state["sm"]["addrset_over"])))
    ipc = out["issued"] / max(out["cycles"], 1)
    out["ipc"] = round(ipc, 4)
    return out


def comparable(stats: dict) -> dict:
    """The subset that must be IDENTICAL across execution modes."""
    keys = ("issued", "issued_mem", "l1_hit", "l1_miss", "l2_hit", "l2_miss",
            "dram_req", "dram_row_hit", "ctas_launched", "cycles",
            "unique_addrs", "cycles_issue", "stall", "warp_cycles")
    return {k: stats[k] for k in keys}
