"""Deterministic stat reduction — the paper's epilogue gather.

Per-SM counters are integers, so the reduction is bit-exact regardless of
execution mode or device count.  The per-SM bounded address sets (paper's
set-valued stat, strategy 2) are unioned here, on the host, once.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def finalize(state: dict) -> dict:
    out = {}
    for k, v in state["stats_sm"].items():
        arr = np.asarray(v).astype(np.int64)
        out[k] = int(arr.sum())
        out[f"{k}_per_sm"] = arr
    for k, v in state["stats"].items():
        out[k] = int(v)
    out["cycles"] = int(state["ctrl"].get("total_cycles",
                                          state["ctrl"]["cycle"]))
    # truncation accounting: kernels that hit max_cycles before finishing
    # (engine.run_workload* count them; done_cycle stayed negative).  Kept
    # out of comparable() — it is run-harness metadata, not timing state.
    out["timeouts"] = int(state["ctrl"].get("timeouts", 0))
    out["timeout"] = out["timeouts"] > 0
    # set-valued stat: union of per-SM address sets
    aset = np.asarray(state["sm"]["addrset"]).ravel()
    out["unique_addrs"] = int(np.unique(aset[aset >= 0]).size)
    out["addrset_overflow"] = int(np.sum(
        np.asarray(state["sm"]["addrset_over"])))
    ipc = out["issued"] / max(out["cycles"], 1)
    out["ipc"] = round(ipc, 4)
    # opt-in telemetry (core/telemetry.py): cumulative lockstep-waste and
    # the number of timeline samples taken.  Harness metadata like the
    # timeout counters — NOT part of comparable(), so telemetry-on runs
    # stay bit-identical to telemetry-off runs on the comparable subset.
    if "telem" in state:
        out["lockstep_waste"] = int(np.asarray(state["telem"]["waste"]))
        out["telemetry_samples"] = int(np.asarray(state["telem"]["idx"]))
    return out


def to_jsonable(obj):
    """Recursively convert a stats/manifest payload to JSON-safe builtins:
    numpy arrays → lists, numpy/jax scalars → int/float, tuples → lists.
    ``finalize`` output carries ``*_per_sm`` int64 arrays that
    ``json.dump`` rejects — every manifest/bench writer funnels through
    here instead of crashing or silently str()-ing them."""
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return float(obj)
    if obj is None or isinstance(obj, str):
        return obj
    if hasattr(obj, "__array__"):          # numpy / jax arrays
        arr = np.asarray(obj)
        if arr.ndim == 0:
            return arr.item()
        return arr.tolist()
    return str(obj)                        # last resort: stable repr


def comparable(stats: dict) -> dict:
    """The subset that must be IDENTICAL across execution modes."""
    keys = ("issued", "issued_mem", "l1_hit", "l1_miss", "l2_hit", "l2_miss",
            "dram_req", "dram_row_hit", "ctas_launched", "cycles",
            "unique_addrs", "cycles_issue", "stall", "warp_cycles")
    return {k: stats[k] for k in keys}
