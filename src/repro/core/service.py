"""Simulation-as-a-service: continuous batching of sim jobs, one warm process.

The ROADMAP's serving open item, built on everything the batching PRs
paid for: clients submit *jobs* — a zoo/``trace:<x>`` workload name or an
uploaded SASS trace text, plus a config-override lane or a ``--sample-*``
style grid — and ONE persistent process packs every pending job into pair
lanes (core/sweep.py:pair_sweep), so unrelated submissions share compiled
programs, the in-process AOT executable cache, and jax's persistent
compilation cache.  Nobody pays compile or cold-start twice.

Pipeline per batch (the scheduler thread, ``_worker``):

  admit    ``build_job`` validates every field by NAME (``ServiceError``,
           mirroring sim/traceio.py:TraceFormatError), resolves the
           workload, rejects oversized CTAs via
           core/batch.py:check_workload_fits, and rejects overrides that
           would change the server's one StaticConfig shape.
  form     pending jobs accumulate until ``batch_lanes`` lanes are
           waiting, the oldest job has waited ``max_wait_s``, or a client
           flushes — then the ENTIRE queue drains into one batch (FIFO,
           so no job can starve: every formation takes everything).
  pack     the batch's (workload, cfg) lanes run through ``pair_sweep``:
           same-footprint jobs grouped by bucket_workloads(plan.bucket_by)
           share one compiled program, and ``lane_quantum`` rounds each
           bucket's lane count up by repeating LIVE lanes — padded slots
           carry real requests, not inert NOPs — so drifting batch sizes
           keep hitting the same AOT executables.
  route    per-job results stream back as each batch completes: the
           ``comparable()`` stats per lane, a queue/compile/execute
           latency split, and (opt-in) a per-job run-manifest pointer
           (core/telemetry.py:write_job_manifest).

Determinism contract (tests/test_service.py): every served lane is
bit-identical to a solo ``simulate(workload, cfg)`` run regardless of
which jobs it was co-batched with, arrival order, or batch boundaries.

The server core is transport-free; launch/serve.py wires it to a
line-JSON protocol over stdin or a TCP socket and documents the schema
(benchmarks/README.md).  ``start=False`` gives tests a synchronous
server: ``run_pending()`` forms exactly one batch, so batch boundaries
are test-controlled.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field

from repro.core import stats as S
from repro.core.plan import RunPlan
from repro.core.sweep import pair_sweep
from repro.sim.config import (DYNAMIC_FIELDS, N_CLASSES, SCHEDULERS, TINY,
                              GPUConfig, split_config)

# override keys a job's config lane may carry (all dynamic — the server
# compiles for ONE StaticConfig shape, so shape knobs are not accepted)
CONFIG_KEYS = DYNAMIC_FIELDS + ("scheduler", "lat_of_class", "disp_of_class")


class ServiceError(ValueError):
    """Malformed or inadmissible submission; names the offending field
    (the serving analogue of sim/traceio.py:TraceFormatError)."""

    def __init__(self, msg: str, fieldname: str | None = None):
        self.field = fieldname
        where = f"field {fieldname!r}: " if fieldname else ""
        super().__init__(f"{where}{msg}")


@dataclass
class Job:
    """One admitted submission: ≥1 (workload, cfg) pair lanes plus the
    bookkeeping the result router fills in."""
    seq: int                       # server-assigned job number
    id: str                        # client id (defaults to "job-<seq>")
    name: str                      # workload name
    pairs: list                    # [(Workload, GPUConfig), ...] lanes
    submitted_t: float = 0.0
    started_t: float = 0.0
    done_t: float = 0.0
    stats: list = None             # per-lane finalized stat dicts
    batch: dict = None             # batch-level timings / packing info
    manifest: str | None = None
    error: str | None = None
    _event: threading.Event = field(default_factory=threading.Event)

    @property
    def n_lanes(self) -> int:
        return len(self.pairs)

    def wait(self, timeout: float = None) -> bool:
        return self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def latency(self) -> dict:
        """The queue/compile/execute split the per-job manifests record:
        how long the job sat in the queue, its batch's compile and
        execute walls (shared across the batch's jobs — a warm batch
        reports compile_s == 0.0), and end-to-end total."""
        batch = self.batch or {}
        return {
            "queue_s": round(max(self.started_t - self.submitted_t, 0.0), 4),
            "compile_s": batch.get("compile_s"),
            "execute_s": batch.get("execute_s"),
            "total_s": round(max(self.done_t - self.submitted_t, 0.0), 4),
        }

    def response(self) -> dict:
        """The JSON-safe completion payload the protocol streams back."""
        if self.error is not None:
            return {"ok": False, "id": self.id, "job": self.seq,
                    "status": "error", "error": self.error}
        return {
            "ok": True, "id": self.id, "job": self.seq, "status": "done",
            "workload": self.name, "lanes": self.n_lanes,
            "stats": [S.comparable(s) for s in self.stats],
            "latency": self.latency(),
            "batch": self.batch,
            "manifest": self.manifest,
        }


# ---------------------------------------------------------------------------
# submission parsing / admission
# ---------------------------------------------------------------------------

def _as_int(val, fieldname: str) -> int:
    if isinstance(val, bool) or not isinstance(val, (int, float)) \
            or int(val) != val:
        raise ServiceError(f"expected an integer, got {val!r}", fieldname)
    return int(val)


def apply_overrides(base: GPUConfig, overrides: dict,
                    fieldname: str = "config") -> GPUConfig:
    """One config lane from a client override dict.  Only dynamic knobs
    are accepted (the server serves ONE StaticConfig shape); unknown
    keys, bad scheduler names and bad table lengths are rejected by
    name."""
    if not isinstance(overrides, dict):
        raise ServiceError(
            f"expected an object of config overrides, got "
            f"{type(overrides).__name__}", fieldname)
    kw = {}
    for key, val in overrides.items():
        where = f"{fieldname}.{key}"
        if key == "scheduler":
            if val not in SCHEDULERS:
                raise ServiceError(
                    f"unknown scheduler {val!r}; use one of "
                    f"{sorted(SCHEDULERS)}", where)
            kw[key] = val
        elif key in ("lat_of_class", "disp_of_class"):
            if not isinstance(val, (list, tuple)) or len(val) != N_CLASSES:
                raise ServiceError(
                    f"per-class table must have {N_CLASSES} entries",
                    where)
            kw[key] = tuple(_as_int(v, where) for v in val)
        elif key in DYNAMIC_FIELDS:
            kw[key] = _as_int(val, where)
        else:
            raise ServiceError(
                f"unknown config override {key!r}; dynamic knobs are "
                f"{sorted(CONFIG_KEYS)} (shape knobs are fixed per "
                "server)", where)
    try:
        cfg = dataclasses.replace(base, **kw)
    except (ValueError, AssertionError) as e:
        raise ServiceError(str(e), fieldname) from None
    return cfg


def _sample_cfgs(base: GPUConfig, spec: dict) -> list:
    """A ``--sample-*`` style config grid from a job's ``sample`` field:
    ``{"n": N, "lat": [[class, lo, hi], ...], "disp": [...],
    "seed": S?}`` → N lanes stepping (or seeded-sampling) the named
    per-class table entries (launch/dse.py:sample_table_grid)."""
    from repro.launch.dse import sample_table_grid

    if not isinstance(spec, dict):
        raise ServiceError("expected an object like "
                           '{"n": 4, "lat": [["fp32", 2, 8]]}', "sample")
    unknown = set(spec) - {"n", "lat", "disp", "seed"}
    if unknown:
        raise ServiceError(f"unknown sample key(s) {sorted(unknown)}",
                           "sample")
    n = _as_int(spec.get("n", 4), "sample.n")
    if n < 1:
        raise ServiceError(f"lane count must be ≥ 1, got {n}", "sample.n")
    for part in ("lat", "disp"):
        triples = spec.get(part, [])
        if not isinstance(triples, list) or any(
                not isinstance(t, (list, tuple)) or len(t) != 3
                for t in triples):
            raise ServiceError("expected [class, lo, hi] triples",
                               f"sample.{part}")
    seed = spec.get("seed")
    if seed is not None:
        seed = _as_int(seed, "sample.seed")
    try:
        return sample_table_grid(base, n, spec.get("lat", []),
                                 spec.get("disp", []), seed=seed)
    except (KeyError, ValueError) as e:
        raise ServiceError(str(e), "sample") from None


def _workload_from_trace_text(text: str, name: str):
    """Lower an uploaded SASS trace text (sim/traceio.py subset grammar)
    into a Workload named ``trace:<name>``."""
    from repro.sim.trace import Workload
    from repro.sim import traceio

    try:
        parsed = traceio.parse_trace_text(text, path=f"<upload:{name}>")
    except traceio.TraceFormatError as e:
        raise ServiceError(str(e), "trace_text") from None
    kernels = []
    for pk in parsed:
        kt, _ = traceio.lower_kernel(pk)
        kernels.append(kt)
    return Workload(f"trace:{name}", kernels)


def build_job(payload: dict, base: GPUConfig, scfg, seq: int) -> Job:
    """Validate one submission and admit it as a Job, or raise
    ``ServiceError`` naming the offending field.  Checks, in order:
    field types and exclusivity, workload resolution (zoo name /
    ``trace:<x>`` / uploaded trace text), config-lane construction,
    static-shape invariance, and CTA admission
    (core/batch.py:check_workload_fits — a kernel that could never
    dispatch is rejected by name instead of spinning to max_cycles)."""
    from repro.core.batch import check_workload_fits

    if not isinstance(payload, dict):
        raise ServiceError(
            f"submission must be a JSON object, got "
            f"{type(payload).__name__}")
    known = {"op", "id", "workload", "trace_text", "scale", "config",
             "configs", "sample"}
    unknown = set(payload) - known
    if unknown:
        raise ServiceError(f"unknown field(s) {sorted(unknown)}; known "
                           f"fields: {sorted(known - {'op'})}",
                           sorted(unknown)[0])
    job_id = payload.get("id", f"job-{seq}")
    if not isinstance(job_id, str):
        raise ServiceError("job id must be a string", "id")

    wl_name = payload.get("workload")
    trace_text = payload.get("trace_text")
    if (wl_name is None) == (trace_text is None):
        raise ServiceError(
            "exactly one of 'workload' (zoo / trace:<x> name) or "
            "'trace_text' (uploaded SASS trace) is required", "workload")
    scale = payload.get("scale", 1.0)
    if isinstance(scale, bool) or not isinstance(scale, (int, float)) \
            or scale <= 0:
        raise ServiceError(f"scale must be a positive number, got "
                           f"{scale!r}", "scale")

    if trace_text is not None:
        if not isinstance(trace_text, str) or not trace_text.strip():
            raise ServiceError("trace_text must be non-empty SASS trace "
                               "text", "trace_text")
        w = _workload_from_trace_text(trace_text, job_id)
        if scale != 1.0:
            from repro.sim.traceio import scale_trace_workload
            w = scale_trace_workload(w, float(scale))
    else:
        if not isinstance(wl_name, str):
            raise ServiceError("workload must be a name string",
                               "workload")
        from repro.sim.workloads import resolve_workload
        try:
            w = resolve_workload(wl_name, scale=float(scale))
        except (KeyError, FileNotFoundError) as e:
            raise ServiceError(str(e), "workload") from None

    given = [k for k in ("config", "configs", "sample") if k in payload]
    if len(given) > 1:
        raise ServiceError(
            f"'config', 'configs' and 'sample' are exclusive, got "
            f"{given}", given[1])
    if "sample" in payload:
        cfgs = _sample_cfgs(base, payload["sample"])
    elif "configs" in payload:
        lanes = payload["configs"]
        if not isinstance(lanes, list) or not lanes:
            raise ServiceError("configs must be a non-empty list of "
                               "override objects", "configs")
        cfgs = [apply_overrides(base, o, f"configs[{i}]")
                for i, o in enumerate(lanes)]
    else:
        cfgs = [apply_overrides(base, payload.get("config", {}))]

    for i, cfg in enumerate(cfgs):
        got = split_config(cfg)[0]
        if got != scfg:
            raise ServiceError(
                "override changes the server's StaticConfig shape (one "
                "shape = one compiled program family)",
                "config" if len(cfgs) == 1 else f"configs[{i}]")
    try:
        check_workload_fits(scfg, w)
    except ValueError as e:
        raise ServiceError(str(e), "workload") from None
    return Job(seq=seq, id=job_id, name=w.name,
               pairs=[(w, cfg) for cfg in cfgs])


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class SimService:
    """The persistent simulation server core: admission queue, batch
    former, pair-lane executor, result router.  Transport-free — see
    launch/serve.py for the line-JSON frontends.

    ``start=True`` runs the scheduler thread (production / soak shape);
    ``start=False`` leaves batch formation to explicit ``run_pending()``
    calls, which the conformance tests use to place batch boundaries
    exactly where they want them."""

    def __init__(self, base: GPUConfig = TINY, plan: RunPlan = None,
                 batch_lanes: int = 8, max_wait_s: float = 0.05,
                 lane_quantum: int | None = None, start: bool = True,
                 manifests: bool = False, manifest_dir: str = None,
                 on_done=None):
        self.base = base
        self.scfg = split_config(base)[0]
        self.plan = plan if plan is not None else RunPlan(
            max_cycles=1 << 15, bucket_by="shape")
        if self.plan.mesh is not None:
            raise ValueError("SimService serves pair lanes; mesh "
                             "distribution is not wired (RunPlan.mesh "
                             "must be None)")
        self.batch_lanes = max(int(batch_lanes), 1)
        self.max_wait_s = float(max_wait_s)
        self.lane_quantum = lane_quantum
        self.manifests = manifests
        self.manifest_dir = manifest_dir
        self.on_done = on_done          # callback(job) as results route
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: list = []
        self._seq = 0
        self._flush = False
        self._stopping = False
        self._served: list = []
        self.counters = {"submitted": 0, "served": 0, "rejected": 0,
                         "errors": 0, "batches": 0, "lanes": 0,
                         "aot_hits": 0}
        self._started_t = time.time()
        self._thread = None
        if start:
            self._thread = threading.Thread(
                target=self._worker, name="sim-service", daemon=True)
            self._thread.start()

    # -- client surface -----------------------------------------------------

    def submit(self, payload: dict) -> Job:
        """Admit one submission (raises ServiceError on bad input) and
        queue it for the next batch."""
        with self._cond:
            if self._stopping:
                raise ServiceError("server is shutting down")
            self._seq += 1
            seq = self._seq
        try:
            job = build_job(payload, self.base, self.scfg, seq)
        except ServiceError:
            with self._cond:
                self.counters["rejected"] += 1
            raise
        job.submitted_t = time.time()
        with self._cond:
            self._pending.append(job)
            self.counters["submitted"] += 1
            self._cond.notify_all()
        return job

    def flush(self) -> None:
        """Ask the batch former to run the queue now, deadline or not."""
        with self._cond:
            self._flush = True
            self._cond.notify_all()

    def stats(self) -> dict:
        with self._lock:
            return dict(self.counters,
                        pending=len(self._pending),
                        batch_lanes=self.batch_lanes,
                        max_wait_s=self.max_wait_s,
                        uptime_s=round(time.time() - self._started_t, 3),
                        plan=self.plan.describe())

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until the queue is empty and every submitted job has
        routed.  With no scheduler thread, runs the batches inline."""
        deadline = time.time() + timeout
        if self._thread is None:
            while self.run_pending():
                if time.time() > deadline:
                    return False
            return True
        self.flush()
        while time.time() < deadline:
            with self._lock:
                if not self._pending and \
                        self.counters["served"] + self.counters["errors"] \
                        >= self.counters["submitted"]:
                    return True
            self.flush()
            time.sleep(0.005)
        return False

    def shutdown(self, drain: bool = True, timeout: float = 60.0) -> None:
        if drain:
            self.drain(timeout)
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    # -- batch formation ----------------------------------------------------

    def _take_batch(self) -> list:
        """Pop the ENTIRE pending queue (FIFO).  Taking everything each
        time is the no-starvation guarantee: a job can never be passed
        over in favor of later arrivals."""
        jobs, self._pending = self._pending, []
        self._flush = False
        return jobs

    def run_pending(self) -> int:
        """Synchronously form and run ONE batch from whatever is queued.
        Returns the number of jobs served (0 = queue was empty).  The
        test-facing entry point: batch boundaries land exactly where the
        caller's submit/run_pending interleaving puts them."""
        with self._cond:
            jobs = self._take_batch()
        if jobs:
            self._run_batch(jobs)
        return len(jobs)

    def _lanes_waiting(self) -> int:
        return sum(j.n_lanes for j in self._pending)

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._stopping and not self._ready_locked():
                    oldest = (self._pending[0].submitted_t
                              if self._pending else None)
                    wait = None
                    if oldest is not None:
                        wait = max(oldest + self.max_wait_s - time.time(),
                                   0.001)
                    self._cond.wait(timeout=wait)
                if self._stopping and not self._pending:
                    return
                jobs = self._take_batch()
            if jobs:
                try:
                    self._run_batch(jobs)
                except Exception as e:  # noqa: BLE001 — route, don't die
                    self._fail_batch(jobs, e)

    def _ready_locked(self) -> bool:
        if not self._pending:
            return False
        if self._flush or self._stopping:
            return True
        if self._lanes_waiting() >= self.batch_lanes:
            return True
        return time.time() - self._pending[0].submitted_t >= self.max_wait_s

    # -- execution + result routing -----------------------------------------

    def _run_batch(self, jobs: list) -> None:
        t_start = time.time()
        for j in jobs:
            j.started_t = t_start
        pairs = [p for j in jobs for p in j.pairs]
        result = pair_sweep(pairs, plan=self.plan,
                            lane_quantum=self.lane_quantum)
        t_done = time.time()
        tm = result.timings
        batch_info = {
            "n_jobs": len(jobs), "n_lanes": len(pairs),
            "n_buckets": tm.get("n_buckets"),
            "compile_s": tm.get("compile_s"),
            "execute_s": tm.get("execute_s"),
            "aot_cache": tm.get("aot_cache"),
        }
        with self._lock:
            self.counters["batches"] += 1
            self.counters["lanes"] += len(pairs)
            if tm.get("aot_cache") == "hit":
                self.counters["aot_hits"] += 1
        base = 0
        for job in jobs:
            job.stats = result.stats[base:base + job.n_lanes]
            base += job.n_lanes
            job.batch = batch_info
            job.done_t = t_done
            if self.manifests:
                from repro.core import telemetry
                job.manifest = telemetry.write_job_manifest(
                    job, scfg=self.scfg, out_dir=self.manifest_dir)
            with self._lock:
                self.counters["served"] += 1
                self._served.append(job.seq)
            job._event.set()
            if self.on_done is not None:
                self.on_done(job)

    def _fail_batch(self, jobs: list, err: Exception) -> None:
        """A batch that failed to execute routes the error to every job
        in it rather than leaving clients hanging."""
        for job in jobs:
            job.error = f"{type(err).__name__}: {err}"
            job.done_t = time.time()
            with self._lock:
                self.counters["errors"] += 1
            job._event.set()
            if self.on_done is not None:
                self.on_done(job)
