"""Trace batching: pad + stack kernel traces so whole workloads vmap.

The engine reads a packed kernel trace through two traced scalars —
``n_instr`` (instruction fetch is clipped to ``pc < n_instr``) and
``n_ctas`` (dispatch stops at ``next_cta >= n_ctas``) — so a trace can be
padded without changing a single simulated event:

  · **NOP slots**: instruction arrays grow to a shared ``n_instr_max``;
    the pad region (op 0, no dep, no address) is never fetched because
    every read site clips/gates on the kernel's own ``n_instr``.
  · **Empty kernels**: a workload grows to a shared kernel count with
    ``n_ctas=0`` kernels; the engine's scan body masks them out entirely
    (state passes through, 0 cycles charged — core/engine.py).

After padding, every kernel of every workload shares one array shape, so
kernels stack into a leading scan axis (``stack_kernels``) and whole
workloads stack into a leading *workload-lane* axis (``stack_workloads``)
— the axis ``core/sweep.py:grid_sweep`` vmaps over.  Padding is proven
inert by tests/test_batch_padding.py (padded vs unpadded bit-exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# per-instruction (length-L) fields of a packed kernel trace; everything
# else in the pack dict is a scalar (n_ctas, warps_per_cta, n_instr)
INSTR_FIELDS = ("ops", "dep", "addr_mode", "addr_param")


def check_workload_fits(scfg, workload) -> None:
    """Pre-trace guard: a kernel whose CTA needs more warp slots than an
    SM has (``warps_per_cta > warps_per_sm``) can NEVER dispatch — the
    engine's quantum loop would spin silently until ``max_cycles``.
    Synthetic generators never produce such shapes, but real-trace
    ingestion (sim/traceio.py) can: a 1024-thread CTA is 32 warps, more
    than TINY's 8 slots.  Raise by name instead, and point at the
    lowering knob that splits oversized CTAs."""
    wps = scfg.warps_per_sm
    for k in workload.kernels:
        if k.warps_per_cta > wps:
            raise ValueError(
                f"kernel {k.name!r} of workload {workload.name!r} has "
                f"warps_per_cta={k.warps_per_cta} > warps_per_sm={wps}: "
                "it could never dispatch and would spin to max_cycles.  "
                "Use a larger config, or split oversized CTAs at ingest "
                "(traceio.load_trace(..., max_warps_per_cta=...))")


def pad_packed(packed: dict, n_instr_max: int) -> dict:
    """Pad a packed kernel's instruction arrays to ``n_instr_max`` with
    inert NOP slots.  ``n_instr`` keeps the TRUE length, so the pad region
    is unreachable (pc never enters it, fetch clips below it)."""
    length = int(packed["ops"].shape[0])
    if length > n_instr_max:
        raise ValueError(
            f"kernel has {length} instructions > n_instr_max={n_instr_max}")
    out = dict(packed)
    for f in INSTR_FIELDS:
        out[f] = jnp.pad(packed[f], (0, n_instr_max - length))
    return out


def empty_packed(n_instr_max: int) -> dict:
    """An ``n_ctas=0`` kernel: dispatches nothing, runs nothing.  Used to
    pad workloads to a shared kernel count; the engine scan charges it 0
    cycles and passes state through untouched."""
    i32 = jnp.int32
    return {
        "ops": jnp.zeros((n_instr_max,), i32),
        "dep": jnp.zeros((n_instr_max,), jnp.bool_),
        "addr_mode": jnp.zeros((n_instr_max,), i32),
        "addr_param": jnp.zeros((n_instr_max,), i32),
        "n_ctas": jnp.zeros((), i32),
        "warps_per_cta": jnp.ones((), i32),   # never 0: used as a divisor
        "n_instr": jnp.zeros((), i32),
    }


def stack_kernels(kernels: list, n_instr: int | None = None,
                  n_kernels: int | None = None) -> dict:
    """Pad packed kernels to shared (n_kernels, n_instr) and stack them
    into a leading kernel axis — the axis the engine's ``lax.scan`` runs
    over.  Returns a pytree whose leaves have leading dim ``n_kernels``."""
    if not kernels:
        raise ValueError("empty kernel list")
    lengths = [int(k["ops"].shape[0]) for k in kernels]
    if n_instr is None:
        n_instr = max(lengths)
    if n_kernels is None:
        n_kernels = len(kernels)
    if len(kernels) > n_kernels:
        raise ValueError(
            f"{len(kernels)} kernels > n_kernels={n_kernels}")
    padded = [pad_packed(k, n_instr) for k in kernels]
    padded += [empty_packed(n_instr)] * (n_kernels - len(kernels))
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def stack_workloads(workloads: list) -> dict:
    """Stack whole workloads into a leading workload-lane axis.

    Every kernel of every workload is padded to the global
    (max kernel count, max instruction count); leaves come out shaped
    ``(n_workloads, n_kernels, ...)`` — vmap axis 0 for a multi-workload
    sweep, scan axis 1 inside each lane.
    """
    if not workloads:
        raise ValueError("empty workload list")
    packs = [[k.pack() for k in w.kernels] for w in workloads]
    if any(not p for p in packs):
        raise ValueError("workload with no kernels")
    n_kernels = max(len(p) for p in packs)
    n_instr = max(int(k["ops"].shape[0]) for p in packs for k in p)
    stacks = [stack_kernels(p, n_instr=n_instr, n_kernels=n_kernels)
              for p in packs]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacks)
