"""Trace batching: pad/concat + stack kernel traces so whole workloads vmap.

The engine reads a packed kernel trace through two traced scalars —
``n_instr`` (instruction fetch is clipped to ``pc < n_instr``) and
``n_ctas`` (dispatch stops at ``next_cta >= n_ctas``) — so a trace can be
padded without changing a single simulated event:

  · **NOP slots**: instruction arrays grow to a shared ``n_instr_max``;
    the pad region (op 0, no dep, no address) is never fetched because
    every read site clips/gates on the kernel's own ``n_instr``.
  · **Empty kernels**: a workload grows to a shared kernel count with
    ``n_ctas=0`` kernels; the engine's scan body masks them out entirely
    (state passes through, 0 cycles charged — core/engine.py).

After padding, every kernel of every workload shares one array shape, so
kernels stack into a leading scan axis (``stack_kernels``) and whole
workloads stack into a leading *workload-lane* axis (``stack_workloads``)
— the axis ``core/sweep.py:grid_sweep`` vmaps over.  Padding is proven
inert by tests/test_batch_padding.py (padded vs unpadded bit-exact).

Two additions serve the batching bet (PR 8):

  · **Ragged layout** (``concat_kernels`` / ``concat_workloads``): instead
    of padding every kernel to the longest one, a workload's instruction
    streams are CONCATENATED into one flat array with a per-kernel
    ``instr_base`` offset table — the ``cu_seqlens`` unpadded-varlen idiom.
    Fetch sites add the kernel's base (sim/smcore.py); pc stays
    kernel-local, so every simulated event (address generation included)
    is bit-identical to the padded layout.  A 3-kernel workload with
    lengths (500, 20, 20) carries 540 instruction slots instead of 1500.
  · **Bucketed lane packing** (``bucket_workloads``): split the workload
    lanes of a grid into ≤ max_buckets groups of similar padded shape or
    predicted cost, so each bucket pads only to ITS max and short lanes
    stop riding the longest lane's while_loop horizon
    (core/sweep.py:grid_sweep with ``RunPlan.bucket_by``).  Predicted
    cost is Σ n_instr × n_ctas per workload, refined by per-workload
    cycle/lockstep-waste telemetry recorded in prior run manifests
    (``cost_hints_from_manifests``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# per-instruction (length-L) fields of a packed kernel trace; everything
# else in the pack dict is a scalar (n_ctas, warps_per_cta, n_instr —
# plus instr_base in the ragged layout)
INSTR_FIELDS = ("ops", "dep", "addr_mode", "addr_param")
# per-kernel scalar fields (the leaves a ragged workload scans over)
SCALAR_FIELDS = ("n_ctas", "warps_per_cta", "n_instr")


def check_workload_fits(scfg, workload) -> None:
    """Pre-trace guard: a kernel whose CTA needs more warp slots than an
    SM has (``warps_per_cta > warps_per_sm``) can NEVER dispatch — the
    engine's quantum loop would spin silently until ``max_cycles``.
    Synthetic generators never produce such shapes, but real-trace
    ingestion (sim/traceio.py) can: a 1024-thread CTA is 32 warps, more
    than TINY's 8 slots.  Raise by name instead, and point at the
    lowering knob that splits oversized CTAs."""
    wps = scfg.warps_per_sm
    for k in workload.kernels:
        if k.warps_per_cta > wps:
            raise ValueError(
                f"kernel {k.name!r} of workload {workload.name!r} has "
                f"warps_per_cta={k.warps_per_cta} > warps_per_sm={wps}: "
                "it could never dispatch and would spin to max_cycles.  "
                "Use a larger config, or split oversized CTAs at ingest "
                "(traceio.load_trace(..., max_warps_per_cta=...))")


def pad_packed(packed: dict, n_instr_max: int) -> dict:
    """Pad a packed kernel's instruction arrays to ``n_instr_max`` with
    inert NOP slots.  ``n_instr`` keeps the TRUE length, so the pad region
    is unreachable (pc never enters it, fetch clips below it)."""
    length = int(packed["ops"].shape[0])
    if length > n_instr_max:
        raise ValueError(
            f"kernel has {length} instructions > n_instr_max={n_instr_max}")
    out = dict(packed)
    for f in INSTR_FIELDS:
        out[f] = jnp.pad(packed[f], (0, n_instr_max - length))
    return out


def empty_packed(n_instr_max: int) -> dict:
    """An ``n_ctas=0`` kernel: dispatches nothing, runs nothing.  Used to
    pad workloads to a shared kernel count; the engine scan charges it 0
    cycles and passes state through untouched."""
    i32 = jnp.int32
    return {
        "ops": jnp.zeros((n_instr_max,), i32),
        "dep": jnp.zeros((n_instr_max,), jnp.bool_),
        "addr_mode": jnp.zeros((n_instr_max,), i32),
        "addr_param": jnp.zeros((n_instr_max,), i32),
        "n_ctas": jnp.zeros((), i32),
        "warps_per_cta": jnp.ones((), i32),   # never 0: used as a divisor
        "n_instr": jnp.zeros((), i32),
    }


def stack_kernels(kernels: list, n_instr: int | None = None,
                  n_kernels: int | None = None) -> dict:
    """Pad packed kernels to shared (n_kernels, n_instr) and stack them
    into a leading kernel axis — the axis the engine's ``lax.scan`` runs
    over.  Returns a pytree whose leaves have leading dim ``n_kernels``."""
    if not kernels:
        raise ValueError("empty kernel list")
    lengths = [int(k["ops"].shape[0]) for k in kernels]
    if n_instr is None:
        n_instr = max(lengths)
    if n_kernels is None:
        n_kernels = len(kernels)
    if len(kernels) > n_kernels:
        raise ValueError(
            f"{len(kernels)} kernels > n_kernels={n_kernels}")
    padded = [pad_packed(k, n_instr) for k in kernels]
    padded += [empty_packed(n_instr)] * (n_kernels - len(kernels))
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def stack_workloads(workloads: list) -> dict:
    """Stack whole workloads into a leading workload-lane axis.

    Every kernel of every workload is padded to the global
    (max kernel count, max instruction count); leaves come out shaped
    ``(n_workloads, n_kernels, ...)`` — vmap axis 0 for a multi-workload
    sweep, scan axis 1 inside each lane.
    """
    if not workloads:
        raise ValueError("empty workload list")
    packs = [[k.pack() for k in w.kernels] for w in workloads]
    if any(not p for p in packs):
        raise ValueError("workload with no kernels")
    n_kernels = max(len(p) for p in packs)
    n_instr = max(int(k["ops"].shape[0]) for p in packs for k in p)
    stacks = [stack_kernels(p, n_instr=n_instr, n_kernels=n_kernels)
              for p in packs]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacks)


# ---------------------------------------------------------------------------
# ragged layout: flat instruction streams + per-kernel offset tables
# ---------------------------------------------------------------------------

def concat_kernels(packs: list, n_instr_total: int | None = None,
                   n_kernels: int | None = None) -> dict:
    """Concatenate packed kernels into the ragged workload layout.

    Instruction arrays become ONE flat ``(n_instr_total,)`` array per
    field; per-kernel scalars gain an ``instr_base`` offset table so the
    engine fetches at ``instr_base + pc`` while pc stays kernel-local
    (sim/smcore.py) — the ``cu_seqlens`` unpadded-varlen idiom.  Unlike
    ``stack_kernels`` nothing pays for the longest kernel: the flat
    length is Σ lengths, padded (inert zeros past every base+n_instr)
    only up to a shared ``n_instr_total`` across workloads.
    """
    if not packs:
        raise ValueError("empty kernel list")
    lengths = [int(k["ops"].shape[0]) for k in packs]
    total = sum(lengths)
    if n_instr_total is None:
        n_instr_total = total
    if total > n_instr_total:
        raise ValueError(
            f"{total} instructions > n_instr_total={n_instr_total}")
    if n_kernels is None:
        n_kernels = len(packs)
    if len(packs) > n_kernels:
        raise ValueError(f"{len(packs)} kernels > n_kernels={n_kernels}")
    i32 = jnp.int32
    pad_k = n_kernels - len(packs)
    bases = [0]
    for length in lengths[:-1]:
        bases.append(bases[-1] + length)
    out = {}
    for f in INSTR_FIELDS:
        flat = jnp.concatenate([k[f] for k in packs])
        out[f] = jnp.pad(flat, (0, n_instr_total - total))
    for f in SCALAR_FIELDS:
        fill = 1 if f == "warps_per_cta" else 0   # never a 0 divisor
        out[f] = jnp.asarray([int(k[f]) for k in packs]
                             + [fill] * pad_k, i32)
    out["instr_base"] = jnp.asarray(bases + [0] * pad_k, i32)
    return out


def concat_workloads(workloads: list) -> dict:
    """Ragged counterpart of ``stack_workloads``: each workload's kernels
    concatenate flat (``concat_kernels``), then workloads stack into the
    leading lane axis.  Instruction leaves come out
    ``(n_workloads, n_instr_total_max)``; per-kernel scalars (including
    ``instr_base``) come out ``(n_workloads, n_kernels_max)`` — the
    engine scans the scalars and closes over the flat streams."""
    if not workloads:
        raise ValueError("empty workload list")
    packs = [[k.pack() for k in w.kernels] for w in workloads]
    if any(not p for p in packs):
        raise ValueError("workload with no kernels")
    n_kernels = max(len(p) for p in packs)
    total = max(sum(int(k["ops"].shape[0]) for k in p) for p in packs)
    rag = [concat_kernels(p, n_instr_total=total, n_kernels=n_kernels)
           for p in packs]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rag)


def split_ragged(trace: dict):
    """Split a ragged workload trace into (per-kernel scalars to scan,
    flat instruction streams to close over).  The engine's scan body
    re-merges them into one kernel-trace dict for the SM runner."""
    scan = {f: trace[f] for f in SCALAR_FIELDS + ("instr_base",)}
    flat = {f: trace[f] for f in INSTR_FIELDS}
    return scan, flat


# ---------------------------------------------------------------------------
# bucketed lane packing: group grid lanes by shape / predicted cost
# ---------------------------------------------------------------------------

def workload_cost(workload, cost_hints: dict | None = None) -> float:
    """Predicted simulation cost of one workload: Σ n_instr × n_ctas over
    its kernels — the static proxy for issued-instruction volume.  A
    recorded hint (measured cycles + lockstep waste from a prior run
    manifest, ``cost_hints_from_manifests``) overrides the proxy: real
    stragglers beat static guesses."""
    if cost_hints and workload.name in cost_hints:
        return float(cost_hints[workload.name])
    return float(sum(k.n_instr * k.n_ctas for k in workload.kernels))


def workload_shape(workload) -> tuple:
    """The padded-footprint key: (kernel count, longest kernel's n_instr).
    Workloads sharing it pad each other for free in one bucket."""
    return (len(workload.kernels),
            max(k.n_instr for k in workload.kernels))


def _gap_partition(keys: list, order: list, max_buckets: int) -> list:
    """Split the sorted lane order at the ``max_buckets - 1`` largest
    positive key gaps (zero-width gaps never split — rerun stability)."""
    gaps = [(keys[order[j + 1]] - keys[order[j]], j)
            for j in range(len(order) - 1)]
    cuts = sorted(j for g, j in sorted(gaps, reverse=True)[:max_buckets - 1]
                  if g > 0)
    buckets, start = [], 0
    for j in cuts:
        buckets.append(order[start:j + 1])
        start = j + 1
    buckets.append(order[start:])
    return buckets


def choose_bucket_count(keys: list, overhead: float | None = None,
                        max_k: int = 8) -> int:
    """Cost-model-driven bucket count: pick the k ∈ [1, max_k] whose
    gap-cut partition minimizes predicted TOTAL padded cost

        Σ_buckets |bucket| · max(bucket key)  +  overhead · k

    The first term is what a bucket actually executes (every lane rides
    its bucket's longest lane); without the per-bucket ``overhead`` term
    (one more compiled program per bucket — default: the mean lane cost)
    it is monotone non-increasing in k and the argmin would always be
    "one bucket per distinct key".  Ties break toward fewer buckets.
    """
    n = len(keys)
    if n <= 1:
        return max(n, 1)
    if overhead is None:
        overhead = sum(keys) / n
    order = sorted(range(n), key=lambda i: (keys[i], i))
    best_k, best_cost = 1, None
    for k in range(1, min(max_k, n) + 1):
        buckets = _gap_partition(keys, order, k)
        cost = sum(len(b) * max(keys[i] for i in b) for b in buckets) \
            + overhead * len(buckets)
        if best_cost is None or cost < best_cost:
            best_k, best_cost = k, cost
    return best_k


def bucket_workloads(workloads: list, by: str = "shape",
                     max_buckets: int | None = 4,
                     cost_hints: dict | None = None) -> list:
    """Partition workload-lane indices into ≤ ``max_buckets`` buckets of
    similar padded shape ('shape') or predicted cost ('cost'), so each
    bucket compiles its own program padded only to ITS max and short
    lanes stop riding the longest lane's while_loop horizon.

    ``max_buckets=None`` picks the count automatically by minimizing the
    predicted total padded cost over the bucket keys plus a per-bucket
    compile-overhead term (``choose_bucket_count``) — the
    cost-model-driven mode ``RunPlan(bucket_by='cost', max_buckets=None)``
    reaches; ``core/sweep.py:grid_sweep`` seeds the cost keys from the
    analytical model (core/analytic.py) when no measured manifest hints
    exist.

    Returns a list of index lists covering ``range(len(workloads))``
    exactly once.  Deterministic: lanes are ordered by (key, index) and
    split at the ``max_buckets - 1`` largest key gaps — zero-width gaps
    (identical keys) never split, so bit-for-bit rerun stability holds
    whatever the lane order.
    """
    n = len(workloads)
    if by == "none" or n == 0:
        return [list(range(n))]
    if by == "shape":
        keys = [float(k * l) for k, l in map(workload_shape, workloads)]
    elif by == "cost":
        keys = [workload_cost(w, cost_hints) for w in workloads]
    else:
        raise ValueError(f"unknown bucket policy {by!r}; "
                         "use 'none', 'shape' or 'cost'")
    if max_buckets is None:
        max_buckets = choose_bucket_count(keys)
    order = sorted(range(n), key=lambda i: (keys[i], i))
    return _gap_partition(keys, order, max_buckets)


def cost_hints_from_manifests(run_dir: str = "experiments/runs") -> dict:
    """Harvest measured per-workload cost from prior run manifests
    (core/telemetry.py:write_manifest): for every stats entry carrying a
    workload name, cost = cycles + final recorded ``lockstep_waste``
    (the straggler tax a lane exported to its batch — a lane that wasted
    others' quanta should bucket as if it were that long).  The max
    across lanes/manifests wins; newer manifests override older ones at
    equal key.  Missing/garbled manifests are skipped — hints are an
    optimization, never a correctness input."""
    import glob
    import json
    import os

    hints: dict = {}
    for path in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        waste = {}
        try:
            from repro.core.telemetry import COUNTERS
            col = COUNTERS.index("lockstep_waste")
            for name, rows in (payload.get("timelines") or {}).items():
                if rows:
                    # grid manifests key timelines "<workload>/<cfg>" —
                    # fold the cfg lanes onto the workload, max wins
                    base = name.rsplit("/", 1)[0]
                    waste[base] = max(waste.get(base, 0.0),
                                      float(rows[-1][col]))
        except (ValueError, TypeError, IndexError, ImportError):
            pass
        for entry in payload.get("stats") or []:
            if not isinstance(entry, dict) or "workload" not in entry:
                continue
            try:
                cost = float(entry["cycles"]) + waste.get(
                    entry["workload"], 0.0)
            except (KeyError, TypeError, ValueError):
                continue
            name = entry["workload"]
            hints[name] = max(hints.get(name, 0.0), cost)
    return hints
