"""Search-driven DSE: propose → analytic prune → cycle-accurate verify.

An exhaustive ``sweep`` prices every candidate config at a full
cycle-accurate run.  ``search`` explores the same space at a fraction of
the cost: each round a seeded proposer (uniform random + evolutionary
mutation of the best verified points) emits hundreds-to-thousands of
candidate ``DynConfig`` vectors, the analytical model (core/analytic.py)
scores them ALL in one vectorized matmul, only the predicted-best
``search_topk`` survivors run through the engine — ONE ``sweep()`` call,
one compiled program, per round — and every measured result feeds back
into the model's least-squares calibration before the next round
proposes.  Per round the predicted-vs-measured Spearman rank correlation
is reported, so a drifting surrogate is visible immediately (ACALSim's
propose→prune→verify framing; PPT-GPU's hybrid analytical+cycle-accurate
split).

Determinism: the proposer draws from ``np.random.PCG64(seed)`` only, the
engine is deterministic, argsorts are stable, and least-squares is
deterministic — so the full candidate sequence, the verified top-k and
the final best are bit-reproducible per seed (tests/test_search.py).
The search objective is MINIMUM measured cycles over the space.

Knobs ride the RunPlan: ``search_seed`` / ``search_rounds`` /
``search_topk`` (core/plan.py); candidate volume per round is the
``n_candidates`` argument (launch/dse.py ``--search-cands``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import analytic
from repro.core.analytic import (CostModel, N_PARAMS, P_DISP, P_LAT,
                                 P_SCHED, decode, encode_config)
from repro.core.plan import RunPlan, resolve_plan
from repro.core.sweep import sweep
from repro.sim import features as F
from repro.sim.config import (GPUConfig, N_CLASSES, class_index,
                              split_config)

# fraction of a round's candidates proposed by elite mutation once
# verified elites exist (the rest stay uniform-random immigrants)
MUTATE_FRACTION = 0.5
# per-dimension mutation probability
MUTATE_P = 0.35


@dataclass(frozen=True)
class SearchSpace:
    """Box bounds over the 21-dim candidate vector (analytic.PARAM_NAMES
    order); ``lo[i] == hi[i]`` freezes dimension ``i``."""
    lo: tuple
    hi: tuple

    def __post_init__(self):
        if len(self.lo) != N_PARAMS or len(self.hi) != N_PARAMS:
            raise ValueError(
                f"SearchSpace bounds must have {N_PARAMS} dims, got "
                f"({len(self.lo)}, {len(self.hi)})")
        for i, (a, b) in enumerate(zip(self.lo, self.hi)):
            if a > b:
                raise ValueError(
                    f"SearchSpace dim {i} ({analytic.PARAM_NAMES[i]}): "
                    f"lo={a} > hi={b}")

    @classmethod
    def from_base(cls, base: GPUConfig, spread: float = 2.0,
                  sample_lat=(), sample_disp=()) -> "SearchSpace":
        """Bounds around a base config: every scalar/table entry spans
        [v/spread, v·spread] (integer, ≥ 1 where the engine needs it);
        ``icnt_lat`` is floored at the machine quantum (the Δ ≤ icnt_lat
        exactness invariant, sim/config.py:check_dyn); the inert-by-
        construction zero table entries (lat[ldg]/lat[stg]) stay frozen.
        ``sample_lat``/``sample_disp`` (CLASS, LO, HI) triples — the same
        wire format as the launchers' ``--sample-*`` flags — override the
        corresponding table dimension's bounds."""
        vec = encode_config(base)
        lo, hi = list(map(int, vec)), list(map(int, vec))

        def span(v, floor=1):
            if v <= 0:
                return v, v                 # frozen (inert entries)
            return max(floor, int(round(v / spread))), \
                max(floor, int(round(v * spread)))

        for i in range(len(analytic.P_SCALARS)):
            lo[i], hi[i] = span(int(vec[i]))
        lo[P_SCHED], hi[P_SCHED] = 0, 1
        for c in range(N_CLASSES):
            lo[P_LAT + c], hi[P_LAT + c] = span(int(vec[P_LAT + c]))
            lo[P_DISP + c], hi[P_DISP + c] = span(int(vec[P_DISP + c]))
        icnt_i = analytic.P_SCALARS.index("icnt_lat")
        lo[icnt_i] = max(lo[icnt_i], base.quantum)
        hi[icnt_i] = max(hi[icnt_i], lo[icnt_i])
        for table_base, triples in ((P_LAT, sample_lat),
                                    (P_DISP, sample_disp)):
            for cname, a, b in triples:
                i = table_base + class_index(str(cname))
                lo[i], hi[i] = int(a), int(b)
        return cls(lo=tuple(lo), hi=tuple(hi))

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """n uniform candidates, (n, N_PARAMS) int64."""
        lo = np.asarray(self.lo, np.int64)
        hi = np.asarray(self.hi, np.int64)
        return rng.integers(lo, hi + 1, size=(n, N_PARAMS))

    def mutate(self, rng: np.random.Generator, parents: np.ndarray,
               n: int) -> np.ndarray:
        """n children: each picks a random parent and perturbs each free
        dimension with prob MUTATE_P by a step ∝ the dimension's range."""
        lo = np.asarray(self.lo, np.int64)
        hi = np.asarray(self.hi, np.int64)
        step = np.maximum((hi - lo) // 8, 1)
        picks = parents[rng.integers(len(parents), size=n)]
        flip = rng.random((n, N_PARAMS)) < MUTATE_P
        delta = rng.integers(-step, step + 1, size=(n, N_PARAMS))
        out = np.where(flip, picks + delta, picks)
        return np.clip(out, lo, hi)


@dataclass
class SearchResult:
    scfg: object
    space: SearchSpace
    seed: int
    features: np.ndarray              # the workload's feature vector
    best: dict                        # flat override dict of the winner
    best_cycles: int
    best_stats: dict                  # finalized stats of the winner
    model: CostModel                  # final calibrated surrogate
    rounds: list = field(default_factory=list)   # per-round reports
    verified: list = field(default_factory=list)  # [(vec, cycles, stats)]

    @property
    def n_scored(self) -> int:
        return sum(r["n_scored"] for r in self.rounds)

    @property
    def n_verified(self) -> int:
        return len(self.verified)

    def report(self) -> dict:
        """JSON-safe summary for manifests / the launcher."""
        return {
            "seed": self.seed,
            "best": analytic.describe_vec(
                analytic.encode(self.best)),
            "best_cycles": int(self.best_cycles),
            "n_scored": self.n_scored,
            "n_verified": self.n_verified,
            "calibration": self.model.calib,
            "rounds": self.rounds,
        }


def _dedupe(cands: np.ndarray) -> np.ndarray:
    """Drop duplicate candidate rows, keeping first occurrence (stable —
    part of the per-seed determinism contract)."""
    seen, keep = set(), []
    for i, row in enumerate(cands):
        key = row.tobytes()
        if key not in seen:
            seen.add(key)
            keep.append(i)
    return cands[keep]


def search(workload, space: SearchSpace = None, plan: RunPlan = None,
           seed: int = None, base: GPUConfig = None,
           n_candidates: int = 256, calibrate_from: str | None = None,
           log=None) -> SearchResult:
    """Seeded analytic-prune search for the config minimizing measured
    cycles on ``workload``.

    Per round: propose ``n_candidates`` (uniform random, plus elite
    mutations once measured elites exist) → score ALL of them with the
    analytical surrogate in one vectorized call → verify the predicted
    top ``plan.search_topk`` in ONE cycle-accurate ``sweep()`` →
    recalibrate the surrogate on every measured row so far → report the
    round's predicted-vs-measured rank correlation.

    ``calibrate_from``: a run-manifest directory to warm-start the
    surrogate from (rows recorded by previous search runs of the same
    StaticConfig); None starts from the uncalibrated prior — what the
    determinism tests use, since reading manifests would couple runs.
    """
    plan = resolve_plan(plan, where="search")
    if seed is None:
        seed = plan.search_seed
    base = base or GPUConfig()
    if space is None:
        space = SearchSpace.from_base(base)
    scfg, _ = split_config(base)
    feats = F.workload_features(workload, scfg)
    rng = np.random.Generator(np.random.PCG64(seed))

    rows = []
    if calibrate_from is not None:
        rows = analytic.calibration_rows_from_manifests(
            scfg, calibrate_from if calibrate_from != "" else None)
    model = CostModel.fit(rows, source="manifests") if rows \
        else CostModel.default()

    topk = min(plan.search_topk, n_candidates)
    verified = []                 # (vec, cycles, stats), every round
    seen_keys = set()
    rounds = []
    for rnd in range(plan.search_rounds):
        if verified:
            n_mut = int(n_candidates * MUTATE_FRACTION)
            elites = np.stack([v for v, _, _ in sorted(
                verified, key=lambda t: (t[1], t[0].tobytes()))[:topk]])
            cands = np.concatenate([
                space.mutate(rng, elites, n_mut),
                space.sample(rng, n_candidates - n_mut)])
        else:
            cands = space.sample(rng, n_candidates)
        cands = _dedupe(cands)

        t0 = time.perf_counter()
        scores = model.predict(feats, cands)
        analytic_s = time.perf_counter() - t0
        order = np.argsort(scores, kind="stable")

        # verify the top-k UNSEEN candidates (re-verifying a lane already
        # measured would waste the round's one sweep call)
        top_idx = [int(i) for i in order
                   if cands[i].tobytes() not in seen_keys][:topk]
        if not top_idx:           # space exhausted (tiny/frozen spaces)
            break
        top = cands[top_idx]
        for v in top:
            seen_keys.add(v.tobytes())
        lanes = [(scfg, decode(v)) for v in top]
        res = sweep(workload, lanes, plan=plan)
        measured = np.asarray(res.cycles, np.float64)
        corr = analytic.spearman(scores[top_idx], measured)

        for v, c, st in zip(top, measured, res.stats):
            verified.append((v, float(c), st))
            rows.append((feats, v, float(c)))
        model = CostModel.fit(rows)

        best_i = int(np.argmin(measured))
        rounds.append({
            "round": rnd,
            "n_scored": int(len(cands)),
            "n_verified": int(len(top)),
            "analytic_s": round(analytic_s, 6),
            "analytic_cands_per_s": round(
                len(cands) / max(analytic_s, 1e-9), 1),
            "verify_s": res.timings.get("execute_s"),
            "verify_lanes_per_s": res.timings.get("lanes_per_s"),
            "rank_corr": None if corr is None else round(corr, 4),
            "best_measured": int(measured[best_i]),
            "best_predicted": round(float(scores[top_idx[best_i]]), 1),
            "calibration": model.calib,
        })
        if log:
            log(f"[search] round {rnd}: scored {len(cands)} "
                f"({rounds[-1]['analytic_cands_per_s']}/s analytic), "
                f"verified {len(top)}, rank_corr={rounds[-1]['rank_corr']}"
                f", best={int(measured[best_i])} cycles")

    best_vec, best_cycles, best_stats = min(
        verified, key=lambda t: (t[1], t[0].tobytes()))
    return SearchResult(
        scfg=scfg, space=space, seed=seed, features=feats,
        best=decode(best_vec), best_cycles=int(best_cycles),
        best_stats=best_stats, model=model, rounds=rounds,
        verified=[(v, int(c), st) for v, c, st in verified])
