"""Analytical fast-path cost model — score configs without simulating.

The cycle-accurate engine prices one (workload, config) lane at a full
quantum-loop run; design-space exploration over thousands of candidate
``DynConfig`` points cannot afford that for every point.  This module
prices a candidate in a few hundred numpy flops instead: a linear-in-
coefficients basis built from the workload's instruction-mix features
(sim/features.py) and the candidate's timing parameters, with the
coefficient vector **self-calibrated** against the cycle-accurate
engine's own recorded results — either measured rows harvested from run
manifests under ``experiments/runs/`` (``calibration_rows_from_manifests``)
or the verify sweeps of a running search (core/search.py feeds every
measured top-k batch back into ``CostModel.fit``).

The basis terms mirror the engine's real bounds (PPT-GPU's hybrid
analytical+cycle-accurate framing): an issue-throughput term
(Σ issue[c]·disp[c]), a dependency latency chain (Σ chain[c]·lat[c]),
per-address-mode memory round trips (l1 hit, L2 trip, DRAM trip — the
fitted coefficient of each absorbs that mode's effective miss rate), a
DRAM bandwidth term and per-wave overhead.  Because every term is linear
in the fitted θ, calibration is one least-squares solve and scoring a
candidate batch is one (n × N_BASIS) @ (N_BASIS,) matmul — vectorized
over thousands of candidates.

Candidate encoding: one flat int vector of the 21 dynamic parameters
(6 scalars + sched + lat[7] + disp[7], ``N_PARAMS``), the wire format
shared with core/search.py's proposers; ``decode`` turns a vector into
the flat override dict that ``core/sweep.py:stack_dyn`` accepts.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass, field

import numpy as np

from repro.sim import features as F
from repro.sim.config import (DYNAMIC_FIELDS, LDG, N_CLASSES, SCHEDULERS,
                              static_part)

# ---------------------------------------------------------------------------
# candidate parameter vectors
# ---------------------------------------------------------------------------

# vector layout: the 6 scalar timing fields, the scheduler selector, then
# the two (N_CLASSES,) tables
P_SCALARS = DYNAMIC_FIELDS                  # indices [0, 6)
P_SCHED = len(P_SCALARS)                    # 6
P_LAT = P_SCHED + 1                         # [7, 14)
P_DISP = P_LAT + N_CLASSES                  # [14, 21)
N_PARAMS = P_DISP + N_CLASSES

PARAM_NAMES = tuple(
    list(P_SCALARS) + ["sched"]
    + [f"lat_{c}" for c in range(N_CLASSES)]
    + [f"disp_{c}" for c in range(N_CLASSES)])

_SCHED_NAMES = {v: k for k, v in SCHEDULERS.items()}


def encode(flat: dict) -> np.ndarray:
    """Flat override dict (DYN_KEYS complete, sim/config.py) → (N_PARAMS,)
    int64 vector."""
    v = np.zeros(N_PARAMS, np.int64)
    for i, k in enumerate(P_SCALARS):
        v[i] = int(flat[k])
    v[P_SCHED] = int(flat["sched"])
    v[P_LAT:P_LAT + N_CLASSES] = np.asarray(flat["lat"], np.int64)
    v[P_DISP:P_DISP + N_CLASSES] = np.asarray(flat["disp"], np.int64)
    return v


def encode_config(cfg) -> np.ndarray:
    """GPUConfig → (N_PARAMS,) vector (via its dynamic fields)."""
    flat = {k: getattr(cfg, k) for k in P_SCALARS}
    flat["sched"] = SCHEDULERS[cfg.scheduler]
    flat["lat"] = cfg.lat_of_class
    flat["disp"] = cfg.disp_of_class
    return encode(flat)


def decode(vec) -> dict:
    """(N_PARAMS,) vector → the flat override dict ``stack_dyn`` accepts
    as a ``(StaticConfig, overrides)`` lane."""
    vec = np.asarray(vec)
    d = {k: int(vec[i]) for i, k in enumerate(P_SCALARS)}
    d["sched"] = int(vec[P_SCHED])
    d["lat"] = tuple(int(x) for x in vec[P_LAT:P_LAT + N_CLASSES])
    d["disp"] = tuple(int(x) for x in vec[P_DISP:P_DISP + N_CLASSES])
    return d


def describe_vec(vec) -> dict:
    """Manifest-friendly lane description of a candidate vector — same
    key layout as launch/dse.py:describe so calibration can read both."""
    d = decode(vec)
    sched = d.pop("sched")
    d["scheduler"] = _SCHED_NAMES.get(sched, str(sched))
    d["lat"] = list(d["lat"])
    d["disp"] = list(d["disp"])
    return d


def params_from_lane(lane: dict) -> np.ndarray | None:
    """Parse a manifest lane description (launch/dse.py:describe format)
    back into a parameter vector; None if keys are missing/garbled."""
    try:
        flat = {k: int(lane[k]) for k in P_SCALARS}
        sched = lane.get("sched")
        if sched is None:
            sched = SCHEDULERS[str(lane["scheduler"]).lower()]
        flat["sched"] = int(sched)
        flat["lat"] = [int(x) for x in lane["lat"]]
        flat["disp"] = [int(x) for x in lane["disp"]]
        if len(flat["lat"]) != N_CLASSES or len(flat["disp"]) != N_CLASSES:
            return None
        return encode(flat)
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# basis
# ---------------------------------------------------------------------------

BASIS_NAMES = ("const", "throughput", "lat_chain", "l1_trip",
               "l2_trip_stream", "l2_trip_strided", "l2_trip_random",
               "dram_trip_strided", "dram_trip_random", "dram_bw",
               "waves", "sched_scale")
N_BASIS = len(BASIS_NAMES)


def basis_matrix(feats: np.ndarray, params: np.ndarray) -> np.ndarray:
    """(n, N_BASIS) basis for one workload's features × n candidate
    vectors.  Vectorized over candidates: the analytic scoring hot path.
    """
    params = np.atleast_2d(np.asarray(params, np.float64))
    n = params.shape[0]
    scal = params[:, :P_SCHED]
    l1, l2, part, burst, rowpen, icnt = (scal[:, i] for i in range(6))
    sched = params[:, P_SCHED]
    lat = params[:, P_LAT:P_LAT + N_CLASSES]
    disp = params[:, P_DISP:P_DISP + N_CLASSES]

    issue = feats[F.F_ISSUE:F.F_ISSUE + N_CLASSES]
    chain = feats[F.F_CHAIN:F.F_CHAIN + N_CLASSES].copy()
    chain[LDG] = 0.0                       # LDG's lat entry is inert
    dep_s, dep_t, dep_r = feats[F.F_DEP_LOAD:F.F_DEP_LOAD + F.N_MODES]
    mem_ch = feats[F.F_MEM_CH:F.F_MEM_CH + F.N_MODES].sum()

    l2_trip = l2 + 2.0 * icnt
    dram_trip = part + burst + rowpen
    cols = np.empty((n, N_BASIS), np.float64)
    cols[:, 0] = 1.0
    cols[:, 1] = disp @ issue
    cols[:, 2] = lat @ chain
    cols[:, 3] = (dep_s + dep_t + dep_r) * l1
    cols[:, 4] = dep_s * l2_trip
    cols[:, 5] = dep_t * l2_trip
    cols[:, 6] = dep_r * l2_trip
    cols[:, 7] = dep_t * dram_trip
    cols[:, 8] = dep_r * dram_trip
    cols[:, 9] = mem_ch * burst
    cols[:, 10] = feats[F.F_WAVES]
    cols[:, 11] = feats[F.F_INSTR_SM] * sched
    return cols


# uncalibrated prior: every physical bound contributes once, with the
# random-pattern memory trips assumed mostly missing and the streaming
# ones mostly hitting — good enough to rank candidates before the first
# measured batch arrives (and for the auto-bucket cost keys)
DEFAULT_THETA = np.array(
    [0.0, 1.0, 1.0, 1.0, 0.1, 0.5, 1.0, 0.5, 1.0, 1.0, 0.0, 0.0],
    np.float64)


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average-tie ranks (scipy-free)."""
    x = np.asarray(x, np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    sx = x[order]
    i = 0
    while i < len(sx):
        j = i
        while j + 1 < len(sx) and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j)
        i = j + 1
    return ranks


def spearman(a, b) -> float | None:
    """Spearman rank correlation; None when either side is constant
    (correlation undefined)."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    if len(a) < 2:
        return None
    ra, rb = _rankdata(a), _rankdata(b)
    sa, sb = ra.std(), rb.std()
    if sa == 0.0 or sb == 0.0:
        return None
    return float(np.mean((ra - ra.mean()) * (rb - rb.mean())) / (sa * sb))


# ---------------------------------------------------------------------------
# the calibrated model
# ---------------------------------------------------------------------------

@dataclass
class CostModel:
    """θ over the basis terms + a calibration report.

    ``predict(feats, params)`` scores a candidate batch in one matmul;
    ``fit(rows)`` least-squares-solves θ from measured (features, params,
    cycles) rows and reports in-sample relative error and rank
    correlation — the self-calibration loop's health signals."""
    theta: np.ndarray = field(default_factory=lambda: DEFAULT_THETA.copy())
    calib: dict = field(default_factory=lambda: {"source": "default",
                                                "n_rows": 0})

    def predict(self, feats: np.ndarray, params) -> np.ndarray:
        return basis_matrix(feats, params) @ self.theta

    def predict_one(self, feats: np.ndarray, params_vec) -> float:
        return float(self.predict(feats, np.atleast_2d(params_vec))[0])

    @classmethod
    def default(cls) -> "CostModel":
        return cls()

    @classmethod
    def fit(cls, rows, source: str = "measured") -> "CostModel":
        """Least-squares θ from measured rows: each row is
        (feature_vector, param_vector, measured_cycles).  Falls back to
        the default prior when rows are empty."""
        if not rows:
            return cls.default()
        phi = np.vstack([basis_matrix(f, np.atleast_2d(p))
                         for f, p, _ in rows])
        y = np.asarray([float(c) for _, _, c in rows], np.float64)
        theta, *_ = np.linalg.lstsq(phi, y, rcond=None)
        pred = phi @ theta
        denom = np.maximum(np.abs(y), 1.0)
        rel = np.abs(pred - y) / denom
        calib = {
            "source": source,
            "n_rows": len(rows),
            "mean_rel_err": round(float(rel.mean()), 4),
            "max_rel_err": round(float(rel.max()), 4),
            "rank_corr": spearman(pred, y),
        }
        return cls(theta=np.asarray(theta, np.float64), calib=calib)


# ---------------------------------------------------------------------------
# calibration rows from run manifests
# ---------------------------------------------------------------------------

def calibration_rows_from_manifests(scfg, run_dir: str | None = None) -> list:
    """Harvest (features, params, measured_cycles) calibration rows from
    prior run manifests under ``experiments/runs/``.

    Only manifests that (a) recorded the workload's feature vector
    (search runs write one — core/search.py via launch/dse.py) and
    (b) match this StaticConfig's hash (timing rows from a different
    machine shape would poison the fit) contribute.  Garbled manifests
    are skipped: calibration data is an optimization, never a
    correctness input."""
    from repro.core.telemetry import runs_dir, static_hash

    scfg = static_part(scfg)
    want = static_hash(scfg)
    run_dir = run_dir or runs_dir()
    rows = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        if payload.get("static_config_hash") != want:
            continue
        feats = payload.get("features")
        lanes = payload.get("lanes")
        stats = payload.get("stats")
        if not (isinstance(feats, list) and lanes and stats
                and len(lanes) == len(stats)):
            continue
        feats = np.asarray(feats, np.float64)
        if feats.shape != (F.N_FEATURES,):
            continue
        for lane, stat in zip(lanes, stats):
            if not (isinstance(lane, dict) and isinstance(stat, dict)):
                continue
            vec = params_from_lane(lane)
            try:
                cycles = float(stat["cycles"])
            except (KeyError, TypeError, ValueError):
                continue
            if vec is not None:
                rows.append((feats, vec, cycles))
    return rows


# ---------------------------------------------------------------------------
# predicted workload cost (auto bucket counts, core/batch.py)
# ---------------------------------------------------------------------------

def predicted_workload_cost(workload, scfg, params_vec=None,
                            model: CostModel | None = None) -> float:
    """Model-predicted cycles of one workload under one parameter point —
    the cost key ``core/batch.py`` uses to pick bucket counts when
    ``bucket_by='cost'`` and ``max_buckets`` is unset.  Defaults to the
    uncalibrated prior and the engine's default timing tables."""
    scfg = static_part(scfg)
    if params_vec is None:
        from repro.sim.config import GPUConfig
        params_vec = encode_config(GPUConfig())
    model = model or CostModel.default()
    feats = F.workload_features(workload, scfg)
    return max(model.predict_one(feats, params_vec), 0.0)
