"""RunPlan — the one typed home for every execution knob of a run.

PR 1–6 grew ``sweep(workload, cfgs, mode=, max_cycles=, mesh=,
exchange=, ...)`` one keyword at a time; the batching work (bucketed lane
packing, ragged layouts, early-exit, compile caching) would have added
five more.  ``RunPlan`` collapses that sprawl: a frozen dataclass that
``sweep`` / ``grid_sweep`` / ``simulate`` (core/sweep.py, core/engine.py),
both launchers (via launch/cli.py) and the benchmarks thread through
unchanged — one place to add a knob, one place to validate it.

Fields by concern:

  execution   ``mode`` (seq/vmap), ``mesh`` + ``exchange`` (2-D
              ('cfg','sm') distribution, core/distribute.py),
              ``max_cycles`` (per-kernel quantum-loop horizon),
              ``early_exit`` (entry-converged lanes charge zero quanta —
              core/engine.py).
  packing     ``bucket_by`` ('none' | 'shape' | 'cost'): split the
              workload lanes of a grid into ≤ ``max_buckets`` buckets of
              similar padded shape / predicted cost and compile one
              program per bucket, so short lanes stop riding the longest
              lane's while_loop (core/batch.py:bucket_workloads).
              ``layout`` ('padded' | 'ragged'): per-bucket trace layout —
              'ragged' concatenates kernels with an ``instr_base`` offset
              table (the cu_seqlens unpadded-varlen idiom) instead of
              NOP-padding every kernel to the longest one.
  telemetry   ``telemetry_samples`` / ``telemetry_every`` — applied to
              the lanes' StaticConfig (all-lanes-or-none) by
              ``apply_telemetry``.
  caching     ``cache_dir`` — persistent XLA compilation cache directory
              (amortizes compiles across *processes*);  ``aot_cache`` —
              in-process memo of AOT-compiled executables keyed on
              (StaticConfig, input shapes, plan knobs), so re-sweeping a
              known bucket shape skips lower+compile entirely
              (core/sweep.py:timed_call).

Legacy keyword compatibility: ``resolve_plan`` lets the old flat kwargs
(`mode=`, `max_cycles=`, `mesh=`, `exchange=`) keep working for one
release — they build a RunPlan and warn once (DeprecationWarning).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass

MODES = ("seq", "vmap")
EXCHANGES = ("window", "cycle")
BUCKET_POLICIES = ("none", "shape", "cost")
LAYOUTS = ("padded", "ragged")


@dataclass(frozen=True)
class RunPlan:
    """Every execution knob of a ``sweep``/``grid_sweep``/``simulate``
    call, validated once at construction.  See the module docstring for
    the field-by-field story."""
    # execution
    mode: str = "vmap"
    mesh: object = None          # jax.sharding.Mesh with ('cfg','sm') axes
    exchange: str = "window"
    max_cycles: int = 1 << 20
    early_exit: bool = True
    # packing.  max_buckets=None with bucket_by='cost' picks the bucket
    # count automatically by minimizing the analytically-predicted total
    # padded cost (core/batch.py:choose_bucket_count); with other
    # policies None falls back to the classic ceiling of 4.
    bucket_by: str = "none"
    max_buckets: int | None = 4
    layout: str = "padded"
    # telemetry (sized into the lanes' StaticConfig — all lanes or none)
    telemetry_samples: int = 0
    telemetry_every: int = 1
    # compile caching
    cache_dir: str | None = None
    aot_cache: bool = True
    # analytic-prune search (core/search.py): proposer seed, rounds of
    # propose→score→verify, and how many predicted-best candidates each
    # round's ONE cycle-accurate sweep verifies
    search_seed: int = 0
    search_rounds: int = 3
    search_topk: int = 8

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"RunPlan.mode must be one of {MODES}, got {self.mode!r} "
                "(SM-axis 'shard' execution is reached via mesh=, not "
                "mode=)")
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"RunPlan.exchange must be one of {EXCHANGES}, got "
                f"{self.exchange!r}")
        if self.bucket_by not in BUCKET_POLICIES:
            raise ValueError(
                f"RunPlan.bucket_by must be one of {BUCKET_POLICIES}, got "
                f"{self.bucket_by!r}")
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"RunPlan.layout must be one of {LAYOUTS}, got "
                f"{self.layout!r}")
        if self.max_cycles <= 0:
            raise ValueError(
                f"RunPlan.max_cycles must be positive, got "
                f"{self.max_cycles}")
        if self.max_buckets is not None and self.max_buckets < 1:
            raise ValueError(
                f"RunPlan.max_buckets must be ≥ 1 (or None for the "
                f"cost-model-driven automatic count), got "
                f"{self.max_buckets}")
        if self.search_seed < 0:
            raise ValueError(
                f"RunPlan.search_seed must be ≥ 0, got {self.search_seed}")
        if self.search_rounds < 1:
            raise ValueError(
                f"RunPlan.search_rounds must be ≥ 1, got "
                f"{self.search_rounds}")
        if self.search_topk < 1:
            raise ValueError(
                f"RunPlan.search_topk must be ≥ 1, got {self.search_topk}")
        if self.telemetry_samples < 0:
            raise ValueError(
                f"RunPlan.telemetry_samples must be ≥ 0, got "
                f"{self.telemetry_samples}")
        if self.telemetry_every < 1:
            raise ValueError(
                f"RunPlan.telemetry_every must be ≥ 1, got "
                f"{self.telemetry_every}")
        if self.mesh is not None:
            if self.mode != "vmap":
                raise ValueError(
                    f"RunPlan.mode={self.mode!r} conflicts with mesh=: the "
                    "distributed path has its own in-lane execution "
                    "(sharded SM axis); use mode='vmap' (the default) or "
                    "drop mesh=")
            names = tuple(getattr(self.mesh, "axis_names", ()))
            if "cfg" not in names or "sm" not in names:
                raise ValueError(
                    "RunPlan.mesh must be a 2-D ('cfg','sm') mesh "
                    f"(core/distribute.py:make_mesh), got axes {names}")

    # -- telemetry ----------------------------------------------------------

    def apply_telemetry(self, cfgs):
        """Size the counter-timeline buffer into every lane's static half
        (no-op when ``telemetry_samples == 0``).  Lanes may be full
        GPUConfig / StaticConfig objects or pre-split ``(StaticConfig,
        overrides)`` pairs — all of them must share one StaticConfig, so
        telemetry is all-lanes-or-none."""
        if self.telemetry_samples <= 0:
            return cfgs
        kw = dict(telemetry_samples=self.telemetry_samples,
                  telemetry_every=self.telemetry_every)

        def one(c):
            if isinstance(c, tuple) and len(c) == 2:
                return (dataclasses.replace(c[0], **kw), c[1])
            return dataclasses.replace(c, **kw)

        if isinstance(cfgs, (list, tuple)):
            return [one(c) for c in cfgs]
        return one(cfgs)

    # -- cache wiring -------------------------------------------------------

    def activate_caches(self) -> None:
        """Wire the persistent XLA compilation cache when ``cache_dir`` is
        set (idempotent; safe to call per sweep)."""
        if self.cache_dir:
            enable_persistent_cache(self.cache_dir)

    def describe(self) -> dict:
        """JSON-safe summary for run manifests / bench artifacts."""
        mesh = None
        if self.mesh is not None:
            mesh = [int(self.mesh.shape["cfg"]), int(self.mesh.shape["sm"])]
        return {
            "mode": self.mode, "mesh": mesh, "exchange": self.exchange,
            "max_cycles": self.max_cycles, "early_exit": self.early_exit,
            "bucket_by": self.bucket_by, "max_buckets": self.max_buckets,
            "layout": self.layout,
            "telemetry_samples": self.telemetry_samples,
            "telemetry_every": self.telemetry_every,
            "cache_dir": self.cache_dir, "aot_cache": self.aot_cache,
            "search_seed": self.search_seed,
            "search_rounds": self.search_rounds,
            "search_topk": self.search_topk,
        }


# ---------------------------------------------------------------------------
# legacy flat-kwarg shim (one release: warn once, then drop)
# ---------------------------------------------------------------------------

_warned_legacy = False


def _warn_legacy_once(where: str) -> None:
    global _warned_legacy
    if not _warned_legacy:
        _warned_legacy = True
        warnings.warn(
            f"{where} received legacy flat keyword(s) (mode=/max_cycles=/"
            "mesh=/exchange=); pass plan=RunPlan(...) instead — the flat "
            "kwargs build a RunPlan for you now and will be removed next "
            "release.", DeprecationWarning, stacklevel=4)


def resolve_plan(plan, *, where: str = "sweep", mode=None, max_cycles=None,
                 mesh=None, exchange=None) -> RunPlan:
    """The one entry point ``sweep``/``grid_sweep``/``simulate`` funnel
    their arguments through.

    ``plan`` given → legacy kwargs must be absent (mixing the two would
    leave a knob with two homes).  ``plan`` absent → any legacy kwargs
    build one (warn once); a bare string in the plan slot is tolerated as
    the old positional ``mode``."""
    if isinstance(plan, str):          # old positional: sweep(w, cfgs, "seq")
        if mode is not None:
            raise ValueError(f"{where}: mode given twice ({plan!r} and "
                             f"{mode!r})")
        plan, mode = None, plan
    legacy = {k: v for k, v in (("mode", mode), ("max_cycles", max_cycles),
                                ("mesh", mesh), ("exchange", exchange))
              if v is not None}
    if plan is not None:
        if legacy:
            raise ValueError(
                f"{where}: pass either plan= or the legacy flat kwargs "
                f"({sorted(legacy)}), not both — every knob lives on the "
                "RunPlan now")
        if not isinstance(plan, RunPlan):
            raise TypeError(
                f"{where}: plan must be a RunPlan, got {type(plan).__name__}")
        return plan
    if legacy:
        _warn_legacy_once(where)
    return RunPlan(**legacy)


# ---------------------------------------------------------------------------
# persistent compilation cache
# ---------------------------------------------------------------------------

_persistent_cache_dir = None


def enable_persistent_cache(cache_dir: str) -> str | None:
    """Point jax's persistent compilation cache at ``cache_dir`` so
    compiled programs survive the process — the ~17 s mesh-grid compile is
    paid once per (StaticConfig, bucket shape), not once per run.

    Idempotent; re-wiring to a *different* directory raises (jax reads the
    config at compile time, silently splitting the cache would be worse).
    Returns the active directory, or None when this jax build has no
    compilation-cache config (the knobs are then best-effort skipped —
    the in-process AOT cache in core/sweep.py still works)."""
    global _persistent_cache_dir
    import os

    import jax

    if _persistent_cache_dir is not None:
        if os.path.abspath(cache_dir) != _persistent_cache_dir:
            raise ValueError(
                f"persistent compile cache already wired to "
                f"{_persistent_cache_dir}; refusing to re-wire to "
                f"{cache_dir} mid-process")
        return _persistent_cache_dir
    cache_dir = os.path.abspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except AttributeError:          # ancient jax: no persistent cache at all
        return None
    # cache every program, however small/fast — simulator programs are
    # worth re-using even when XLA thinks they compiled "quickly"
    for knob, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                      ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(knob, val)
        except AttributeError:
            pass
    _persistent_cache_dir = cache_dir
    return cache_dir
