"""Execution modes for the SM phase — the paper's `#pragma omp parallel for`.

  'seq'   — lax.map over SMs: one SM at a time (single-thread reference)
  'vmap'  — vectorized over the SM axis (single-chip SIMD parallelism)
  'shard' — shard_map over an 'sm' device mesh axis: each device simulates
            its SM shard; the serial region (memory system + CTA dispatch)
            is computed REPLICATED from an all-gathered request table, which
            preserves sequential semantics bit-exactly at any device count.

SM→device assignment ("OpenMP scheduler" analogue):
  'static'  — contiguous SM blocks per device
  'dynamic' — deterministic load-aware deal: SMs dealt round-robin so early
              (CTA-heavy under round-robin dispatch) SMs spread evenly.
Both are pure relabelings of the SM axis — simulation results are identical;
only per-device work balance changes (reported by benchmarks/scheduler.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import telemetry
from repro.sim.config import GPUConfig, split_config, static_part
from repro.sim.cta import cta_issue
from repro.sim.memsys import mem_phase
from repro.sim.smcore import sm_quantum_single


def make_sm_runner(cfg, mode: str = "vmap", mesh: Mesh = None):
    """Returns sm_runner(warp, sm, req, stats_sm, trace, t0, dyn).

    cfg may be a full GPUConfig or just its StaticConfig half — only static
    shape fields are closed over; all timing numerics flow in via ``dyn``
    (the typed DynConfig pytree — replicated under shard_map, vmapped over
    lanes by core/sweep.py; the spec/tree plumbing below is pytree-generic
    so the grouped, table-valued leaves need no special casing).

    mode='shard' needs a ``mesh`` with an 'sm' axis: the SM phase runs
    under shard_map over that axis (each device vmaps its SM block), while
    the serial region stays on the full replicated arrays in
    ``engine.quantum_step`` — one entry point for every execution mode.
    For the fully sharded quantum (serial region recomputed replicated
    from an all-gather inside the shard region) see
    ``make_sharded_quantum`` / ``core/distribute.py``.
    """
    scfg = static_part(cfg)

    if mode == "vmap":
        def runner(warp, sm, req, stats_sm, trace, t0, dyn):
            return jax.vmap(
                lambda w, s, r, st: sm_quantum_single(
                    w, s, r, st, trace, t0, scfg, dyn))(
                warp, sm, req, stats_sm)
        return runner

    if mode == "seq":
        def runner(warp, sm, req, stats_sm, trace, t0, dyn):
            return jax.lax.map(
                lambda a: sm_quantum_single(a[0], a[1], a[2], a[3], trace,
                                            t0, scfg, dyn),
                (warp, sm, req, stats_sm))
        return runner

    if mode == "shard":
        if mesh is None or "sm" not in mesh.axis_names:
            raise ValueError(
                "mode='shard' needs mesh= with an 'sm' axis, e.g. "
                "make_sm_runner(cfg, 'shard', make_host_mesh(n, 'sm'))")
        from jax.experimental.shard_map import shard_map

        if len(mesh.axis_names) > 1:
            # Slice out a 1-D ('sm',) submesh: a shard_map whose specs
            # never mention some mesh axis mis-replicates across compiled
            # loop iterations under check_rep=False (the claim is trusted,
            # not enforced), so this runner — whose loop lives OUTSIDE the
            # shard region in engine.quantum_step — must own every axis of
            # the mesh it runs on.  Lane-parallel execution over a full
            # 2-D ('cfg', 'sm') mesh is core/distribute.py's job, where
            # the whole loop sits inside one shard_map.
            axis = mesh.axis_names.index("sm")
            devs = mesh.devices[tuple(
                slice(None) if i == axis else 0
                for i in range(mesh.devices.ndim))]
            mesh = Mesh(devs, ("sm",))

        n_dev = mesh.shape["sm"]
        if scfg.n_sm % n_dev:
            raise ValueError(
                f"n_sm={scfg.n_sm} not divisible by mesh 'sm' axis "
                f"size {n_dev}")
        sm_spec, rep = P("sm"), P()

        def spec_like(tree, spec):
            return jax.tree_util.tree_map(lambda _: spec, tree)

        def runner(warp, sm, req, stats_sm, trace, t0, dyn):
            def local(warp, sm, req, stats_sm, trace, t0, dyn):
                return jax.vmap(
                    lambda w, s, r, st: sm_quantum_single(
                        w, s, r, st, trace, t0, scfg, dyn))(
                    warp, sm, req, stats_sm)

            parts = (warp, sm, req, stats_sm)
            in_specs = tuple(spec_like(p, sm_spec) for p in parts) + (
                spec_like(trace, rep), rep, spec_like(dyn, rep))
            out_specs = tuple(spec_like(p, sm_spec) for p in parts)
            fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
            return fn(warp, sm, req, stats_sm, trace, t0, dyn)
        return runner

    raise ValueError(f"unknown mode {mode!r} (expected seq/vmap/shard)")


def make_shard_body(cfg, n_dev: int, exchange: str = "window"):
    """The per-device quantum step for SM-axis sharding — a plain traced
    function of LOCAL shards, written against mesh axis name 'sm'.

    ``body(warp, sm, req, stats_sm, mem, ctrl, gstats, trace, dyn)`` where
    warp/sm/req/stats_sm hold this device's SM block (n_sm // n_dev rows)
    and mem/ctrl/gstats/trace/dyn are replicated.  The serial region
    all-gathers the (small) request table and warp arrays over 'sm',
    computes identical results on every device, and each device then runs
    its SM shard locally for Δ cycles.

    Factored out of ``make_sharded_quantum`` so the same body serves the
    1-D ('sm',) mesh (below) and the 2-D ('cfg', 'sm') mesh
    (core/distribute.py), where it additionally runs vmapped over the
    device-local config lanes — collectives stay per-'sm'-group, so each
    lane remains bit-identical to its solo run.
    """
    scfg = static_part(cfg)
    assert scfg.n_sm % n_dev == 0, (scfg.n_sm, n_dev)
    chunk = scfg.n_sm // n_dev

    def body(warp, sm, req, stats_sm, mem, ctrl, gstats, trace, dyn):
        t0 = ctrl["cycle"]
        # --- serial region, replicated ---------------------------------
        req_f = jax.tree_util.tree_map(
            lambda x: jax.lax.all_gather(x, "sm", axis=0, tiled=True), req)
        warp_f = jax.tree_util.tree_map(
            lambda x: jax.lax.all_gather(x, "sm", axis=0, tiled=True), warp)
        req_f, mem, gstats = mem_phase(req_f, mem, gstats, t0, scfg, dyn,
                                       sm_ids=ctrl["sm_ids"])
        warp_f, ctrl, gstats = cta_issue(warp_f, dict(ctrl), gstats, trace,
                                         scfg)
        i = jax.lax.axis_index("sm")
        take = lambda x: jax.lax.dynamic_slice_in_dim(  # noqa: E731
            x, i * chunk, chunk, axis=0)
        req_l = jax.tree_util.tree_map(take, req_f)
        warp_l = jax.tree_util.tree_map(take, warp_f)
        # --- parallel region: my SM shard ------------------------------
        if exchange == "cycle":
            # emulate a per-cycle barrier: gather the table every cycle
            from repro.sim.smcore import sm_cycle_single

            def cyc(i, carry):
                warp_l, sm, req_l, stats_sm, dbg = carry
                warp_l, sm, req_l, stats_sm = jax.vmap(
                    lambda w, s, r, st: sm_cycle_single(
                        w, s, r, st, trace, t0 + i, scfg, dyn))(
                    warp_l, sm, req_l, stats_sm)
                gathered = jax.lax.all_gather(req_l["stage"], "sm", axis=0,
                                              tiled=True)
                dbg = dbg + jnp.sum(gathered, dtype=jnp.int32) * 0
                return warp_l, sm, req_l, stats_sm, dbg

            warp_l, sm, req_l, stats_sm, _ = jax.lax.fori_loop(
                0, scfg.quantum, cyc,
                (warp_l, sm, req_l, stats_sm, jnp.zeros((), jnp.int32)))
        else:
            warp_l, sm, req_l, stats_sm = jax.vmap(
                lambda w, s, r, st: sm_quantum_single(w, s, r, st, trace, t0,
                                                      scfg, dyn))(
                warp_l, sm, req_l, stats_sm)
        # --- done detection (replicated) --------------------------------
        from repro.core.engine import converged

        cycle_end = t0 + scfg.quantum
        done = converged(ctrl, warp_l, req_l, trace, axis_name="sm")
        done_cycle = jnp.where((ctrl["done_cycle"] < 0) & done, cycle_end,
                               ctrl["done_cycle"])
        ctrl = dict(ctrl, cycle=cycle_end, done_cycle=done_cycle)
        return warp_l, sm, req_l, stats_sm, mem, ctrl, gstats

    return body


def make_sharded_quantum(cfg: GPUConfig, mesh: Mesh,
                         exchange: str = "window"):
    """The whole quantum step under shard_map (engine.quantum_step analogue).

    Per-SM arrays are sharded over the 'sm' axis; mem/ctrl/global-stats are
    replicated — see ``make_shard_body`` for the per-device step.

    exchange='window' — one all-gather per quantum (the lookahead window,
    beyond-paper optimization).  exchange='cycle' — additionally all-gathers
    every inner cycle, emulating the paper's per-cycle OpenMP barrier;
    results are bit-identical, only communication frequency differs.
    """
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape["sm"]
    body = make_shard_body(cfg, n_dev, exchange)

    sm_spec = P("sm")
    rep = P()

    def spec_like(tree, spec):
        return jax.tree_util.tree_map(lambda _: spec, tree)

    def sharded_step(state, trace, dyn):
        in_specs = (spec_like(state["warp"], sm_spec),
                    spec_like(state["sm"], sm_spec),
                    spec_like(state["req"], sm_spec),
                    spec_like(state["stats_sm"], sm_spec),
                    spec_like(state["mem"], rep),
                    spec_like(state["ctrl"], rep),
                    spec_like(state["stats"], rep),
                    spec_like(trace, rep),
                    spec_like(dyn, rep))
        out_specs = in_specs[:7]
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        warp, sm, req, stats_sm, mem, ctrl, gstats = fn(
            state["warp"], state["sm"], state["req"], state["stats_sm"],
            state["mem"], state["ctrl"], state["stats"], trace, dyn)
        out = {"warp": warp, "sm": sm, "req": req, "mem": mem,
               "ctrl": ctrl, "stats_sm": stats_sm, "stats": gstats}
        # telemetry runs OUTSIDE the shard region, where the out_specs
        # have reassembled the full per-SM arrays — no collectives needed
        if "telem" in state:
            out["telem"] = telemetry.quantum_update(
                state["telem"], out, trace, static_part(cfg))
        return out

    return sharded_step


def run_kernel_sharded(state, trace, cfg: GPUConfig, mesh: Mesh,
                       max_cycles: int = 1 << 20, exchange: str = "window",
                       dyn: dict = None, early_exit: bool = True):
    if dyn is None:
        _, dyn = split_config(cfg)
    step = make_sharded_quantum(cfg, mesh, exchange)

    def cond(st):
        return (st["ctrl"]["done_cycle"] < 0) & \
            (st["ctrl"]["cycle"] < max_cycles)

    def body(st):
        return step(st, trace, dyn)

    if early_exit:
        # state here holds the FULL per-SM arrays (out_specs reassemble
        # outside the shard region), so no collective is needed
        from repro.core.engine import mark_entry_converged
        state = mark_entry_converged(state, trace)
    state = jax.lax.while_loop(cond, body, state)
    if "telem" in state:
        state = dict(state, telem=telemetry.sample(
            state["telem"], state, static_part(cfg), force=True))
    return state


# ---------------------------------------------------------------------------
# SM→device assignment (the OpenMP scheduler analogue)
# ---------------------------------------------------------------------------

def sm_permutation(cfg: GPUConfig, n_devices: int,
                   policy: str = "static") -> np.ndarray:
    sms = np.arange(cfg.n_sm)
    if policy == "static":
        return sms
    if policy == "dynamic":
        # deal SMs round-robin to devices, then concatenate per-device lists
        per_dev = [sms[d::n_devices] for d in range(n_devices)]
        return np.concatenate(per_dev)
    raise ValueError(policy)


def permute_state(state: dict, perm: np.ndarray) -> dict:
    """Relabel the SM axis: array position p now holds SM ``perm[p]``.
    ctrl.sm_ids records the original ids so CTA dispatch (round-robin over
    original ids) is invariant — only the device placement changes."""
    idx = jnp.asarray(perm, jnp.int32)
    out = dict(state)
    for part in ("warp", "sm", "req", "stats_sm"):
        out[part] = jax.tree_util.tree_map(lambda x: x[idx], state[part])
    out["ctrl"] = dict(state["ctrl"], sm_ids=state["ctrl"]["sm_ids"][idx])
    return out
