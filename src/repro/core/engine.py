"""Deterministic simulation engine: quantum loop (Algorithm 1, windowed).

Each machine quantum (Δ=16 cycles):
  1. memory phase   (serial region, lines 8–19)   — full request table
  2. CTA dispatch   (serial region, line 25)      — quantum boundary
  3. SM phase ×Δ    (parallel region, lines 20–23) — per-SM, local

The SM phase runner is injected (core/parallel.py) so the same engine body
serves the sequential, vectorized and sharded execution modes — results are
bit-identical by construction (tests/test_sim_determinism.py).

Config threading: the engine takes the hashable ``StaticConfig`` (jit-static
shapes) and the ``dyn`` pytree of traced timing parameters separately.  All
timing numerics enter the compiled program as *arguments*, never as Python
constants, so ``core/sweep.py`` can vmap the whole engine over a batch of
dynamic configs (one design-space-exploration lane per config).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sim.config import GPUConfig, StaticConfig, split_config
from repro.sim.cta import cta_issue
from repro.sim.memsys import mem_phase
from repro.sim.state import init_state, reset_for_kernel
from repro.sim.trace import Workload


def quantum_step(state: dict, trace: dict, cfg: StaticConfig, dyn: dict,
                 sm_runner):
    t0 = state["ctrl"]["cycle"]
    req, mem, gstats = mem_phase(state["req"], state["mem"], state["stats"],
                                 t0, cfg, dyn,
                                 sm_ids=state["ctrl"]["sm_ids"])
    warp, ctrl, gstats = cta_issue(state["warp"], dict(state["ctrl"]),
                                   gstats, trace, cfg)
    warp, sm, req, stats_sm = sm_runner(warp, state["sm"], req,
                                        state["stats_sm"], trace, t0, dyn)
    cycle_end = t0 + cfg.quantum
    n_instr = trace["n_instr"]
    live = warp["active"] & ~((warp["pc"] >= n_instr)
                              & (warp["pending"] == 0))
    done = (ctrl["next_cta"] >= trace["n_ctas"]) & ~jnp.any(live) & \
        jnp.all(req["stage"] == 0)
    done_cycle = jnp.where((ctrl["done_cycle"] < 0) & done, cycle_end,
                           ctrl["done_cycle"])
    ctrl = dict(ctrl, cycle=cycle_end, done_cycle=done_cycle)
    return {"warp": warp, "sm": sm, "req": req, "mem": mem, "ctrl": ctrl,
            "stats_sm": stats_sm, "stats": gstats}


def run_kernel(state: dict, trace: dict, cfg: StaticConfig, dyn: dict,
               sm_runner, max_cycles: int = 1 << 20):
    def cond(st):
        return (st["ctrl"]["done_cycle"] < 0) & \
            (st["ctrl"]["cycle"] < max_cycles)

    def body(st):
        return quantum_step(st, trace, cfg, dyn, sm_runner)

    return jax.lax.while_loop(cond, body, state)


def kernel_cycles(ctrl: dict):
    """Cycles charged to the kernel that just ran: its done_cycle, or the
    current clock if it hit max_cycles.  The ONE accounting rule every
    execution mode shares (solo, vmapped sweep, sharded)."""
    return jnp.where(ctrl["done_cycle"] >= 0, ctrl["done_cycle"],
                     ctrl["cycle"])


def run_workload(state: dict, kernels: list, cfg: StaticConfig, dyn: dict,
                 sm_runner=None, max_cycles: int = 1 << 20,
                 state_transform=None, kernel_runner=None) -> dict:
    """Run packed kernels back-to-back, accumulating total cycles.

    With the default kernel_runner this is a pure traced function of
    (state, dyn): jit it once, or vmap it over a stacked ``dyn`` batch for
    a design-space sweep (core/sweep.py).  Pass ``kernel_runner`` —
    ``(state, packed, dyn) -> state`` — to substitute a pre-jitted or
    sharded per-kernel step while keeping this accounting loop shared.
    """
    if kernel_runner is None:
        def kernel_runner(st, packed, d):
            return run_kernel(st, packed, cfg, d, sm_runner, max_cycles)
    total_cycles = jnp.zeros((), jnp.int32)
    for packed in kernels:
        state = reset_for_kernel(state, cfg)
        if state_transform is not None:
            state = state_transform(state)
        state = kernel_runner(state, packed, dyn)
        total_cycles = total_cycles + kernel_cycles(state["ctrl"])
    state["ctrl"]["total_cycles"] = total_cycles
    return state


def simulate(workload: Workload, cfg: GPUConfig, sm_runner,
             max_cycles: int = 1 << 20, jit: bool = True,
             state_transform=None) -> dict:
    """Run all kernels of a workload; returns the final state."""
    scfg, dyn = split_config(cfg)
    runner = partial(run_kernel, cfg=scfg, sm_runner=sm_runner,
                     max_cycles=max_cycles)
    if jit:
        runner = jax.jit(runner)
    return run_workload(
        init_state(scfg), [k.pack() for k in workload.kernels], scfg, dyn,
        state_transform=state_transform,
        kernel_runner=lambda st, packed, d: runner(st, packed, dyn=d))
