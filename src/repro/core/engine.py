"""Deterministic simulation engine: quantum loop (Algorithm 1, windowed).

Each machine quantum (Δ=16 cycles):
  1. memory phase   (serial region, lines 8–19)   — full request table
  2. CTA dispatch   (serial region, line 25)      — quantum boundary
  3. SM phase ×Δ    (parallel region, lines 20–23) — per-SM, local

The SM phase runner is injected (core/parallel.py) so the same engine body
serves the sequential, vectorized and sharded execution modes — results are
bit-identical by construction (tests/test_sim_determinism.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sim.config import GPUConfig
from repro.sim.cta import cta_issue
from repro.sim.memsys import mem_phase
from repro.sim.state import init_state, reset_for_kernel
from repro.sim.trace import Workload


def quantum_step(state: dict, trace: dict, cfg: GPUConfig, sm_runner):
    t0 = state["ctrl"]["cycle"]
    req, mem, gstats = mem_phase(state["req"], state["mem"], state["stats"],
                                 t0, cfg, sm_ids=state["ctrl"]["sm_ids"])
    warp, ctrl, gstats = cta_issue(state["warp"], dict(state["ctrl"]),
                                   gstats, trace, cfg)
    warp, sm, req, stats_sm = sm_runner(warp, state["sm"], req,
                                        state["stats_sm"], trace, t0)
    cycle_end = t0 + cfg.quantum
    n_instr = trace["n_instr"]
    live = warp["active"] & ~((warp["pc"] >= n_instr)
                              & (warp["pending"] == 0))
    done = (ctrl["next_cta"] >= trace["n_ctas"]) & ~jnp.any(live) & \
        jnp.all(req["stage"] == 0)
    done_cycle = jnp.where((ctrl["done_cycle"] < 0) & done, cycle_end,
                           ctrl["done_cycle"])
    ctrl = dict(ctrl, cycle=cycle_end, done_cycle=done_cycle)
    return {"warp": warp, "sm": sm, "req": req, "mem": mem, "ctrl": ctrl,
            "stats_sm": stats_sm, "stats": gstats}


def run_kernel(state: dict, trace: dict, cfg: GPUConfig, sm_runner,
               max_cycles: int = 1 << 20):
    def cond(st):
        return (st["ctrl"]["done_cycle"] < 0) & \
            (st["ctrl"]["cycle"] < max_cycles)

    def body(st):
        return quantum_step(st, trace, cfg, sm_runner)

    return jax.lax.while_loop(cond, body, state)


def simulate(workload: Workload, cfg: GPUConfig, sm_runner,
             max_cycles: int = 1 << 20, jit: bool = True,
             state_transform=None) -> dict:
    """Run all kernels of a workload; returns the final state."""
    state = init_state(cfg)
    runner = partial(run_kernel, cfg=cfg, sm_runner=sm_runner,
                     max_cycles=max_cycles)
    if jit:
        runner = jax.jit(runner, static_argnames=())
    total_cycles = jnp.zeros((), jnp.int32)
    for kernel in workload.kernels:
        state = reset_for_kernel(state, cfg)
        if state_transform is not None:
            state = state_transform(state)
        state = runner(state, kernel.pack())
        kc = jnp.where(state["ctrl"]["done_cycle"] >= 0,
                       state["ctrl"]["done_cycle"],
                       state["ctrl"]["cycle"])
        total_cycles = total_cycles + kc
    state["ctrl"]["total_cycles"] = total_cycles
    return state
