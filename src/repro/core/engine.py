"""Deterministic simulation engine: quantum loop (Algorithm 1, windowed).

Each machine quantum (Δ=16 cycles):
  1. memory phase   (serial region, lines 8–19)   — full request table
  2. CTA dispatch   (serial region, line 25)      — quantum boundary
  3. SM phase ×Δ    (parallel region, lines 20–23) — per-SM, local

The SM phase runner is injected (core/parallel.py) so the same engine body
serves the sequential, vectorized and sharded execution modes — results are
bit-identical by construction (tests/test_sim_determinism.py).

Config threading: the engine takes the hashable ``StaticConfig`` (jit-static
shapes) and the typed ``DynConfig`` pytree of traced timing parameters
separately.  All timing numerics — scalar latencies AND the per-class
``core.lat``/``core.disp`` tables — enter the compiled program as
*arguments*, never as Python constants, so ``core/sweep.py`` can vmap the
whole engine over a batch of dynamic configs (one design-space-exploration
lane per config, ~20+ sweepable entries each).

Kernel threading: a workload's kernels are padded + stacked
(core/batch.py) and run by a ``lax.scan`` over the kernel axis
(``run_workload_stacked``) — the whole workload is ONE traced program, so
``core/sweep.py:grid_sweep`` can additionally vmap over a stacked batch
of *workloads* (benchmarks × configs in one compiled call).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.sim.config import DynConfig, GPUConfig, StaticConfig, split_config
from repro.sim.cta import cta_issue
from repro.sim.memsys import mem_phase
from repro.sim.state import init_state, reset_for_kernel
from repro.sim.trace import Workload


def converged(ctrl: dict, warp: dict, req: dict, trace: dict,
              axis_name=None):
    """The ONE kernel-completion predicate every execution mode shares:
    all CTAs dispatched, no live warp (active with work left or loads
    pending), no in-flight memory request.  Pass ``axis_name`` when warp/
    req hold only this device's SM shard — the counts psum over that mesh
    axis so every device sees the full-machine verdict."""
    live = warp["active"] & ~((warp["pc"] >= trace["n_instr"])
                              & (warp["pending"] == 0))
    n_live = jnp.sum(live, dtype=jnp.int32)
    n_busy = jnp.sum(jnp.asarray(req["stage"] != 0), dtype=jnp.int32)
    if axis_name is not None:
        n_live = jax.lax.psum(n_live, axis_name)
        n_busy = jax.lax.psum(n_busy, axis_name)
    return (ctrl["next_cta"] >= trace["n_ctas"]) & (n_live == 0) & \
        (n_busy == 0)


def mark_entry_converged(state: dict, trace: dict, axis_name=None) -> dict:
    """Early-exit: stamp ``done_cycle`` BEFORE the quantum while_loop when
    the kernel is already converged at entry, so the loop runs ZERO
    iterations instead of burning one full quantum discovering it.

    After ``reset_for_kernel`` only an ``n_ctas == 0`` padding kernel can
    be entry-converged (``next_cta`` starts at 0, so any real kernel still
    has CTAs to dispatch) — and the workload scan masks those kernels'
    state and cycles out entirely — so this is bit-exact by construction.
    The savings are real though: every empty slot a short workload padded
    up to the grid's kernel count previously cost a full quantum_step
    (serial region + Δ SM cycles + collectives on the distributed path).
    """
    entry = converged(state["ctrl"], state["warp"], state["req"], trace,
                      axis_name)
    dc = jnp.where((state["ctrl"]["done_cycle"] < 0) & entry,
                   state["ctrl"]["cycle"], state["ctrl"]["done_cycle"])
    return dict(state, ctrl=dict(state["ctrl"], done_cycle=dc))


def quantum_step(state: dict, trace: dict, cfg: StaticConfig,
                 dyn: DynConfig, sm_runner):
    t0 = state["ctrl"]["cycle"]
    req, mem, gstats = mem_phase(state["req"], state["mem"], state["stats"],
                                 t0, cfg, dyn,
                                 sm_ids=state["ctrl"]["sm_ids"])
    warp, ctrl, gstats = cta_issue(state["warp"], dict(state["ctrl"]),
                                   gstats, trace, cfg)
    warp, sm, req, stats_sm = sm_runner(warp, state["sm"], req,
                                        state["stats_sm"], trace, t0, dyn)
    cycle_end = t0 + cfg.quantum
    done = converged(ctrl, warp, req, trace)
    done_cycle = jnp.where((ctrl["done_cycle"] < 0) & done, cycle_end,
                           ctrl["done_cycle"])
    ctrl = dict(ctrl, cycle=cycle_end, done_cycle=done_cycle)
    out = {"warp": warp, "sm": sm, "req": req, "mem": mem, "ctrl": ctrl,
           "stats_sm": stats_sm, "stats": gstats}
    # opt-in counter timeline: statically gated, so the compiled program
    # is unchanged when telemetry is off (core/telemetry.py)
    if telemetry.enabled(cfg):
        out["telem"] = telemetry.quantum_update(state["telem"], out,
                                                trace, cfg)
    return out


def run_kernel(state: dict, trace: dict, cfg: StaticConfig,
               dyn: DynConfig, sm_runner, max_cycles: int = 1 << 20,
               early_exit: bool = True):
    def cond(st):
        return (st["ctrl"]["done_cycle"] < 0) & \
            (st["ctrl"]["cycle"] < max_cycles)

    def body(st):
        return quantum_step(st, trace, cfg, dyn, sm_runner)

    if early_exit:
        state = mark_entry_converged(state, trace)
    state = jax.lax.while_loop(cond, body, state)
    # force a final snapshot per kernel so the last written timeline row
    # always equals the final cumulative counters (core/telemetry.py)
    if telemetry.enabled(cfg):
        state = dict(state, telem=telemetry.sample(
            state["telem"], state, cfg, force=True))
    return state


def kernel_cycles(ctrl: dict):
    """Cycles charged to the kernel that just ran: its done_cycle, or the
    current clock if it hit max_cycles.  The ONE accounting rule every
    execution mode shares (solo, vmapped sweep, sharded)."""
    return jnp.where(ctrl["done_cycle"] >= 0, ctrl["done_cycle"],
                     ctrl["cycle"])


def run_workload_stacked(state: dict, stacked: dict, cfg: StaticConfig,
                         dyn: DynConfig, sm_runner, max_cycles: int = 1 << 20,
                         state_transform=None, kernel_runner=None,
                         early_exit: bool = True) -> dict:
    """Run a whole workload as ONE traced program: ``lax.scan`` over the
    stacked kernel axis (core/batch.py:stack_kernels).

    Per scan step: traced state reset (sim/state.py:reset_for_kernel),
    run the kernel to completion, accumulate its cycles.  Padding kernels
    (``n_ctas == 0``) are masked out — the carried state passes through
    unchanged and 0 cycles are charged — so a workload padded to a shared
    kernel count is bit-identical to its unpadded self.  With
    ``early_exit`` (default) those padding kernels also cost ~zero WORK:
    they are converged at entry, so the quantum while_loop runs zero
    iterations (``mark_entry_converged``) instead of one full quantum.
    A kernel that hits ``max_cycles`` (``done_cycle`` still < 0) bumps
    the ``timeouts`` counter so truncated runs are reported, not silently
    counted as complete (core/stats.py:finalize → ``timeout``).

    The stacked trace may be in either layout (core/batch.py): padded —
    every leaf has leading kernel axis — or RAGGED (``instr_base``
    present) — per-kernel scalars scan while the flat concatenated
    instruction streams are closed over and re-merged per step, so short
    kernels stop paying for the longest kernel's NOP slots.

    Being a single traced function of (state, stacked, dyn), this is what
    ``core/sweep.py`` vmaps over workload and config lanes.

    ``kernel_runner`` — ``(state, packed, dyn) -> state`` — substitutes the
    default ``run_kernel`` quantum loop with a custom traced one (e.g. the
    SM-sharded step of core/distribute.py, where ``state``'s per-SM arrays
    hold only this device's shard and ``cfg`` is the matching local-shape
    StaticConfig).  The scan, per-kernel reset, empty-kernel masking and
    timeout accounting stay shared across every execution mode.
    """
    zero = jnp.zeros((), jnp.int32)
    ragged = "instr_base" in stacked
    if ragged:
        from repro.core.batch import split_ragged
        scan_xs, flat = split_ragged(stacked)
    else:
        scan_xs, flat = stacked, {}

    def body(carry, scanned):
        prev, total, timeouts = carry
        packed = dict(flat, **scanned) if ragged else scanned
        st = reset_for_kernel(prev, cfg)
        if state_transform is not None:
            st = state_transform(st)
        if kernel_runner is None:
            st = run_kernel(st, packed, cfg, dyn, sm_runner, max_cycles,
                            early_exit)
        else:
            st = kernel_runner(st, packed, dyn)
        empty = packed["n_ctas"] == 0
        total = total + jnp.where(empty, 0, kernel_cycles(st["ctrl"]))
        timeouts = timeouts + jnp.where(
            ~empty & (st["ctrl"]["done_cycle"] < 0), 1, 0)
        nxt = jax.tree_util.tree_map(
            lambda old, new: jnp.where(empty, old, new), prev, st)
        return (nxt, total, timeouts), None

    (state, total, timeouts), _ = jax.lax.scan(
        body, (state, zero, zero), scan_xs)
    return dict(state, ctrl=dict(state["ctrl"], total_cycles=total,
                                 timeouts=timeouts))


def run_workload(state: dict, kernels: list, cfg: StaticConfig,
                 dyn: DynConfig, sm_runner=None, max_cycles: int = 1 << 20,
                 state_transform=None, kernel_runner=None) -> dict:
    """Run packed kernels back-to-back, accumulating total cycles.

    Default path: the kernel list is padded + stacked (core/batch.py) and
    handed to ``run_workload_stacked`` — one ``lax.scan``, one compiled
    kernel body regardless of kernel count; a pure traced function of
    (state, dyn) that core/sweep.py jits/vmaps whole.  Pass
    ``kernel_runner`` — ``(state, packed, dyn) -> state`` — to substitute
    a pre-jitted or sharded per-kernel step; that path keeps the host
    loop (per-kernel device programs) but shares the same accounting,
    including the ``timeouts`` truncation counter.
    """
    if kernel_runner is None:
        from repro.core.batch import stack_kernels
        return run_workload_stacked(state, stack_kernels(kernels), cfg, dyn,
                                    sm_runner, max_cycles, state_transform)
    total_cycles = jnp.zeros((), jnp.int32)
    timeouts = jnp.zeros((), jnp.int32)
    for packed in kernels:
        state = reset_for_kernel(state, cfg)
        if state_transform is not None:
            state = state_transform(state)
        state = kernel_runner(state, packed, dyn)
        total_cycles = total_cycles + kernel_cycles(state["ctrl"])
        timeouts = timeouts + jnp.where(state["ctrl"]["done_cycle"] < 0,
                                        1, 0)
    state["ctrl"]["total_cycles"] = total_cycles
    state["ctrl"]["timeouts"] = timeouts
    return state


def simulate(workload: Workload, cfg: GPUConfig, sm_runner,
             max_cycles: int = None, jit: bool = True,
             state_transform=None, plan=None) -> dict:
    """Run all kernels of a workload; returns the final state.

    The whole workload — state init, per-kernel reset, every kernel's
    quantum loop — is one traced program (``lax.scan`` over the stacked
    kernel axis), jitted once.

    Execution knobs (max_cycles, early_exit, trace layout, cache dir)
    come from ``plan=`` (core/plan.py:RunPlan); the bare ``max_cycles=``
    keyword still works for one release via the deprecation shim."""
    from repro.core.batch import (check_workload_fits, concat_kernels,
                                  stack_kernels)
    from repro.core.plan import resolve_plan

    plan = resolve_plan(plan, where="simulate", max_cycles=max_cycles)
    plan.activate_caches()
    scfg, dyn = split_config(cfg)
    check_workload_fits(scfg, workload)
    packs = [k.pack() for k in workload.kernels]
    stacked = (concat_kernels(packs) if plan.layout == "ragged"
               else stack_kernels(packs))

    def run(state0, d):
        return run_workload_stacked(state0, stacked, scfg, d,
                                    sm_runner, plan.max_cycles,
                                    state_transform,
                                    early_exit=plan.early_exit)

    if jit:
        # the freshly-built initial state is argument 0 and DONATED: the
        # final state aliases its buffers instead of holding two copies
        run = jax.jit(run, donate_argnums=(0,))
    return run(init_state(scfg), dyn)
