"""2-D ('cfg', 'sm') mesh distribution — sweeps across devices.

PR 1/2 made the benchmarks × configs grid ONE compiled program
(core/sweep.py), but every lane still lived on one device; the SM-axis
sharding (core/parallel.py) conversely knew nothing about lanes.  This
module unifies the two behind one mesh abstraction:

  · the lane axis of ``sweep()`` / ``grid_sweep()`` is sharded over the
    mesh's **'cfg'** axis — config lanes are perfectly independent, so
    this needs NO communication (ScaleSimulator's near-linear regime);
  · within each lane, the SM axis is sharded over the **'sm'** axis using
    the same per-device quantum body as the 1-D shard mode
    (core/parallel.py:make_shard_body): the serial region is recomputed
    REPLICATED from an all-gather over 'sm', which preserves sequential
    semantics bit-exactly.

Each device therefore simulates its (config-shard × SM-shard) block, and
every lane is bit-identical to its solo single-device run at ANY mesh
shape — 1×N, N×1, A×B (tests/test_mesh_sweep.py).  The lane-stacked
dynamic pytree placed over 'cfg' is the typed ``DynConfig``: its scalar
leaves shard as (n_lanes,) and the per-class ``core.lat``/``core.disp``
tables as (n_lanes, N_CLASSES) — ``P('cfg')`` touches only the leading
lane axis, so table-valued sweeps distribute exactly like scalar ones.  All simulator state is
int32, so there is no floating-point reassociation to worry about either.

CPU recipe (jax locks the device count at first init, so set this before
importing jax — or use the subprocess helpers in benchmarks/):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.launch.zoo --grid 4 4 --mesh 2 2 --check
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import telemetry
from repro.core.engine import run_workload_stacked
from repro.core.parallel import make_shard_body
from repro.sim.config import StaticConfig, static_part

CFG_AXIS, SM_AXIS = "cfg", "sm"

# state parts with a leading n_sm axis (sharded over 'sm'); the rest —
# mem/ctrl/stats — are replicated within an 'sm' group (sim/state.py).
SHARDED_PARTS = ("warp", "sm", "req", "stats_sm")
STATE_PARTS = ("warp", "sm", "req", "mem", "ctrl", "stats_sm", "stats")


def make_mesh(n_cfg: int, n_sm: int = 1) -> Mesh:
    """2-D ('cfg', 'sm') device mesh over the first n_cfg × n_sm devices.

    Either axis may be 1 (1×N = pure SM sharding, N×1 = pure lane
    sharding), so one mesh type serves every distribution shape.
    """
    need = n_cfg * n_sm
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh ({n_cfg}, {n_sm}) needs {need} devices, have "
            f"{len(devices)} — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "in the environment before jax initializes.")
    return Mesh(np.asarray(devices[:need]).reshape(n_cfg, n_sm),
                (CFG_AXIS, SM_AXIS))


def state_specs(*prefix, telem: bool = False) -> dict:
    """PartitionSpec pytree-prefix for a state dict whose leaves carry
    ``prefix`` leading lane axes: per-SM parts additionally shard their SM
    axis over 'sm'; mem/ctrl/stats are replicated within an 'sm' group.
    ``telem`` adds the replicated counter-timeline part present when the
    StaticConfig enables telemetry (core/telemetry.py)."""
    parts = STATE_PARTS + (("telem",) if telem else ())
    return {k: (P(*prefix, SM_AXIS) if k in SHARDED_PARTS else P(*prefix))
            for k in parts}


def check_mesh(mesh: Mesh, scfg: StaticConfig, n_lanes: int) -> None:
    if set(mesh.axis_names) != {CFG_AXIS, SM_AXIS}:
        raise ValueError(
            f"sweep mesh must have axes ('{CFG_AXIS}', '{SM_AXIS}'), got "
            f"{mesh.axis_names} (build one with core.distribute.make_mesh)")
    if n_lanes % mesh.shape[CFG_AXIS]:
        raise ValueError(
            f"{n_lanes} config lanes not divisible by mesh '{CFG_AXIS}' "
            f"axis size {mesh.shape[CFG_AXIS]}")
    if scfg.n_sm % mesh.shape[SM_AXIS]:
        raise ValueError(
            f"n_sm={scfg.n_sm} not divisible by mesh '{SM_AXIS}' axis "
            f"size {mesh.shape[SM_AXIS]}")


def place_lanes(tree, mesh: Mesh, spec: P = None):
    """Place a lane-stacked pytree with an explicit NamedSharding (leading
    lane axis over 'cfg' by default) instead of leaving it to implicit
    single-device placement + transfer at dispatch."""
    sh = NamedSharding(mesh, spec if spec is not None else P(CFG_AXIS))
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


def place_state(state: dict, mesh: Mesh, *prefix) -> dict:
    """Place a host-built batched initial state (core/sweep.py:
    batched_init) with the same per-part shardings the dist runners
    produce (``state_specs``): per-SM parts sharded ('sm' blocks match
    the contiguous slices the old in-region ``local_init`` took), the
    rest replicated within an 'sm' group.  Placing the state OUTSIDE the
    compiled program lets the runners DONATE it — the final state aliases
    these buffers instead of allocating a second full copy."""
    specs = state_specs(*prefix, telem="telem" in state)
    return {k: jax.tree_util.tree_map(
                lambda x, s=specs[k]: jax.device_put(
                    x, NamedSharding(mesh, s)), v)
            for k, v in state.items()}


def make_dist_kernel_runner(scfg: StaticConfig, n_sm_dev: int,
                            exchange: str = "window",
                            max_cycles: int = 1 << 20,
                            early_exit: bool = True):
    """Per-lane kernel quantum loop on LOCAL SM shards — the sharded
    analogue of ``engine.run_kernel``, pluggable into
    ``run_workload_stacked(kernel_runner=...)``."""
    body = make_shard_body(scfg, n_sm_dev, exchange)
    telem_on = telemetry.enabled(scfg)

    def kernel_runner(st, packed, dyn):
        def cond(s):
            return (s["ctrl"]["done_cycle"] < 0) & \
                (s["ctrl"]["cycle"] < max_cycles)

        def step(s):
            warp, sm, req, stats_sm, mem, ctrl, gstats = body(
                s["warp"], s["sm"], s["req"], s["stats_sm"],
                s["mem"], s["ctrl"], s["stats"], packed, dyn)
            out = {"warp": warp, "sm": sm, "req": req, "mem": mem,
                   "ctrl": ctrl, "stats_sm": stats_sm, "stats": gstats}
            if telem_on:
                # per-SM arrays here are this device's shard — the counter
                # sums psum over 'sm' so the replicated buffer row holds
                # full-machine totals, bit-identical on every device
                out["telem"] = telemetry.quantum_update(
                    s["telem"], out, packed, scfg, axis_name=SM_AXIS)
            return out

        if early_exit:
            # entry check runs BEFORE the loop (collectives are illegal in
            # a while_loop cond); warp/req are local shards, so the live/
            # busy counts psum over 'sm' — every device agrees, and an
            # empty padding kernel skips its quantum (all-gathers included)
            from repro.core.engine import mark_entry_converged
            st = mark_entry_converged(st, packed, axis_name=SM_AXIS)
        st = jax.lax.while_loop(cond, step, st)
        if telem_on:
            st = dict(st, telem=telemetry.sample(
                st["telem"], st, scfg, axis_name=SM_AXIS, force=True))
        return st

    return kernel_runner


def _make_lane_runner(scfg: StaticConfig, n_sm_dev: int, exchange: str,
                      max_cycles: int, early_exit: bool = True):
    """One (workload × config) lane, run on this device's SM shard.  The
    kernel-axis scan / reset / timeout accounting is the SHARED engine path
    (run_workload_stacked) — only the per-kernel quantum loop is swapped
    for the sharded one, with a local-shape StaticConfig so the traced
    reset builds shard-sized per-SM arrays."""
    chunk = scfg.n_sm // n_sm_dev
    local_scfg = dataclasses.replace(scfg, n_sm=chunk)
    kernel_runner = make_dist_kernel_runner(scfg, n_sm_dev, exchange,
                                            max_cycles, early_exit)

    def run_lane(st, stacked, dyn):
        # st arrives pre-sharded by the shard_map in_specs: per-SM parts
        # hold this device's contiguous SM block (the same slice the old
        # in-region local_init took via axis_index), ctrl keeps the FULL
        # sm_ids table — the serial region is computed replicated and CTA
        # round-robin follows original ids
        return run_workload_stacked(st, stacked, local_scfg, dyn, None,
                                    max_cycles, kernel_runner=kernel_runner)

    return run_lane


def make_dist_sweep_runner(scfg: StaticConfig, mesh: Mesh,
                           max_cycles: int = 1 << 20,
                           exchange: str = "window",
                           early_exit: bool = True):
    """One compiled program for a config sweep on a ('cfg', 'sm') mesh:
    ``(state_batch, stacked_kernels, dyn_batch) -> batched final
    state``.  Lanes are sharded over 'cfg' (vmap over the device-local
    lanes inside the shard region); each lane's SM axis is sharded over
    'sm'.  The initial state batch (placed by ``place_state``) is
    DONATED — in and out shardings match part-by-part, so the final
    state aliases the input buffers on every device."""
    from jax.experimental.shard_map import shard_map

    scfg = static_part(scfg)
    run_lane = _make_lane_runner(scfg, mesh.shape[SM_AXIS], exchange,
                                 max_cycles, early_exit)
    specs = state_specs(CFG_AXIS, telem=telemetry.enabled(scfg))

    def body(state, stacked, dyn_batch):
        return jax.vmap(run_lane, in_axes=(0, None, 0))(
            state, stacked, dyn_batch)

    fn = shard_map(body, mesh=mesh, in_specs=(specs, P(), P(CFG_AXIS)),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def make_dist_grid_runner(scfg: StaticConfig, mesh: Mesh,
                          max_cycles: int = 1 << 20,
                          exchange: str = "window",
                          early_exit: bool = True):
    """One compiled program for a whole (workload × config) grid on a
    ('cfg', 'sm') mesh — the distributed twin of
    ``core/sweep.py:make_grid_runner``.  The workload axis is replicated
    (every device runs all W workloads for ITS config lanes); the config
    axis is sharded over 'cfg', the SM axis over 'sm'.  The (W, C)
    initial state batch is DONATED, same as the sweep runner."""
    from jax.experimental.shard_map import shard_map

    scfg = static_part(scfg)
    run_lane = _make_lane_runner(scfg, mesh.shape[SM_AXIS], exchange,
                                 max_cycles, early_exit)
    specs = state_specs(None, CFG_AXIS, telem=telemetry.enabled(scfg))

    def body(state, stacked, dyn_batch):
        over_cfgs = jax.vmap(run_lane, in_axes=(0, None, 0))
        return jax.vmap(over_cfgs, in_axes=(0, 0, None))(
            state, stacked, dyn_batch)

    fn = shard_map(body, mesh=mesh, in_specs=(specs, P(), P(CFG_AXIS)),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))
