"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run forces 512 host devices; the
single-pod mesh then uses the first 256.
"""
from __future__ import annotations

import jax

from repro.parallelism.ctx import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this).")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes)


def make_host_mesh(n: int | None = None, axis: str = "sm"):
    """1-D mesh over available host devices (used by the simulator core)."""
    devices = jax.devices()
    n = n or len(devices)
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices[:n]), (axis,))


# 2-D ('cfg', 'sm') sweep meshes are built by repro.core.distribute.make_mesh
# (config lanes over 'cfg', each lane's SM axis over 'sm'); on CPU, force
# host devices BEFORE jax initializes:
# XLA_FLAGS=--xla_force_host_platform_device_count=<n_cfg*n_sm>.


def make_ctx(mesh) -> ShardCtx:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None
    return ShardCtx(mesh=mesh, batch_axes=batch_axes, tp_axis=tp)
