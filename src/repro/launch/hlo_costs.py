"""Loop-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for
scan-over-layers models that under-counts FLOPs/bytes/collectives by the
layer count (and by the chunk count inside attention scans).  This module
re-derives costs from the compiled HLO text with call-graph multipliers:

  flops      — dot ops: 2 · |result| · |contraction|  (MXU work)
  bytes      — operand + result bytes of materializing ops
               (fusion boundaries = HBM traffic; internal temps are free)
  collectives— operand bytes per op kind (all-gather normalized by group)

``while`` bodies are multiplied by ``known_trip_count`` from the backend
config; fusions/calls are inlined.  Validated against analytic 6·N·D counts
in tests/test_hlo_costs.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# NB: tuple types may contain /*index=N*/ comments (with '='), so the type
# group must be permissive; the op token is the first word followed by '('.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?.+?)\s*"
                     r"([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]*n["\s:]*"?(\d+)')
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# HBM-traffic model (fusion-ideal, i.e. what XLA:TPU would materialize —
# XLA:CPU wraps every elementwise op in its own kLoop fusion, so counting
# fusion boundaries would inflate the memory term ~10×):
#   dot          — operands + result
#   ds/gather    — 2 × result (the slice is what moves, not the operand)
#   dus/scatter  — 2 × update operand (in-place on the big buffer)
#   copy/transpose/reduce-window/sort — 2 × result
#   custom-call/convolution — operands + result
#   collectives  — operand bytes (they also appear in the collective term)
#   fusions      — transparent: recurse, inner materializing ops count
#   elementwise/reduce/broadcast/... — fused away, free
_SLICE_OPS = {"dynamic-slice", "gather"}
_UPDATE_OPS = {"dynamic-update-slice": 1, "scatter": 2}
_RESULT2_OPS = {"copy", "transpose", "reduce-window", "sort"}
_FULL_OPS = {"dot", "custom-call", "convolution"}


def _split_args(s: str) -> list[str]:
    """Split an HLO operand list on top-level commas only (shapes like
    ``f32[8,64,64]{2,1,0}`` and tuple types carry nested commas)."""
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _call_args(line: str, op: str) -> str:
    """Balanced-paren extraction of the argument text of ``op(...)``."""
    i = line.find(op + "(")
    if i < 0:
        return ""
    i += len(op) + 1
    depth, j = 1, i
    while j < len(line) and depth:
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
        j += 1
    return line[i:j - 1]


def _operand_name(arg: str) -> str:
    toks = arg.split()
    return toks[-1].lstrip("%") if toks else ""


def _operand_type(arg: str, sym: dict) -> str:
    """Operand type: inline (``f32[16,64]{1,0} %x`` — modern dialect) or
    looked up from the symbol table (bare ``%x``)."""
    toks = arg.split()
    if len(toks) >= 2:
        return " ".join(toks[:-1])
    return sym.get(_operand_name(arg), "")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)

    def add_bytes(self, op: str, b: float):
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b

    def add(self, other: "Costs", mult: float = 1.0,
            include_bytes: bool = True):
        self.flops += other.flops * mult
        if include_bytes:
            self.bytes += other.bytes * mult
            for k, v in other.bytes_by_op.items():
                self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))


def _split_computations(text: str) -> dict:
    comps, cur, name = {}, None, None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                name = m.group(1)
                cur = [line]
        else:
            cur.append(line)
            if line.strip() == "}":
                comps[name] = cur
                cur = None
    return comps


def _entry_name(text: str) -> str | None:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                return m.group(1)
    return None


def analyze(text: str) -> Costs:
    comps = _split_computations(text)
    entry = _entry_name(text)
    memo: dict[str, Costs] = {}

    def comp_cost(name: str, depth: int = 0) -> Costs:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return Costs()
        memo[name] = Costs()          # break cycles defensively
        lines = comps[name]
        # symbol table: defined values + flat header params
        sym: dict[str, str] = {}
        for pname, ptype in _PARAM_RE.findall(lines[0]):
            sym[pname] = ptype
        for line in lines[1:]:
            d = _DEF_RE.match(line)
            if d:
                sym[d.group(1)] = d.group(2)
        total = Costs()
        for line in lines[1:]:
            d = _DEF_RE.match(line)
            if not d:
                continue
            _, rtype, op = d.groups()
            # --- flops: dots --------------------------------------------
            if op == "dot":
                dims = _shape_dims(rtype)
                nres = 1
                for x in dims:
                    nres *= x
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                args = _split_args(_call_args(line, op))
                contr = 1
                if cdims and args:
                    ldims = _shape_dims(_operand_type(args[0], sym))
                    for ci in cdims.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            contr *= ldims[int(ci)]
                total.flops += 2.0 * nres * contr
            # --- bytes ---------------------------------------------------
            def _operands():
                return _split_args(_call_args(line, op))

            base = op[:-6] if op.endswith("-start") else op
            if base in _FULL_OPS:
                b = _type_bytes(rtype)
                for a in _operands():
                    b += _type_bytes(_operand_type(a, sym))
                total.add_bytes(base, b)
            elif base in _SLICE_OPS:
                # 1× result: the consumer (dot) counts the read again
                total.add_bytes(base, _type_bytes(rtype))
            elif base in _RESULT2_OPS:
                total.add_bytes(base, 2 * _type_bytes(rtype))
            elif base in _UPDATE_OPS:
                ops_ = _operands()
                idx = _UPDATE_OPS[base]
                upd_t = _operand_type(ops_[idx], sym) if len(ops_) > idx \
                    else ""
                if upd_t:
                    total.add_bytes(base, 2 * _type_bytes(upd_t))
                else:
                    total.add_bytes(base, 2 * _type_bytes(rtype))
            elif base in _COLLECTIVES:
                total.add_bytes(base, _type_bytes(rtype))
            # --- collectives --------------------------------------------
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                rb = _type_bytes(rtype)
                g = 1
                gm = _GROUPS_RE.search(line)
                if gm:
                    g = max(int(gm.group(2)), 1)
                else:
                    gb = _GROUPS_BRACE_RE.search(line)
                    if gb:
                        g = max(len(gb.group(1).split(",")), 1)
                if base_op == "all-gather":
                    ob = rb / g
                elif base_op == "reduce-scatter":
                    ob = rb * g
                else:
                    ob = rb
                total.coll_bytes[base_op] = \
                    total.coll_bytes.get(base_op, 0.0) + ob
                total.coll_count[base_op] = \
                    total.coll_count.get(base_op, 0) + 1
            # --- calls ---------------------------------------------------
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                for key in ("body", "condition"):
                    cm = re.search(key + r"=%?([\w\.\-]+)", line)
                    if cm:
                        total.add(comp_cost(cm.group(1), depth + 1), trip)
            elif op in ("fusion", "call", "conditional"):
                cm = re.search(r"(?:calls|branch_computations)="
                               r"\{?%?([\w\.\-]+)", line)
                if cm:
                    # fusions are transparent: inner materializing ops
                    # (dot / ds / dus / ...) carry the traffic.
                    total.add(comp_cost(cm.group(1), depth + 1), 1.0)
        memo[name] = total
        return total

    if entry is None:
        return Costs()
    return comp_cost(entry)
