"""Simulator launcher — run paper benchmarks or LM-derived workloads.

  python -m repro.launch.simulate --workload lavaMD --mode vmap
  python -m repro.launch.simulate --arch qwen2-72b --shape train_4k
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import SHAPES, get_config
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import arch_workload, make_workload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="")
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--mode", choices=["seq", "vmap"], default="vmap")
    ap.add_argument("--max-cycles", type=int, default=1 << 17)
    args = ap.parse_args(argv)

    cfg = RTX3080TI
    if args.arch:
        w = arch_workload(get_config(args.arch), SHAPES[args.shape])
    else:
        w = make_workload(args.workload or "hotspot", scale=args.scale)
    t0 = time.time()
    st = simulate(w, cfg, make_sm_runner(cfg, args.mode),
                  max_cycles=args.max_cycles)
    jax.block_until_ready(st["ctrl"]["total_cycles"])
    out = S.finalize(st)
    print(json.dumps({k: v for k, v in S.comparable(out).items()}, indent=1))
    print(f"[simulate] {w.name}: {out['cycles']} GPU cycles, "
          f"ipc={out['ipc']}, wall={time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
