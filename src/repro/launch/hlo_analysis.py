"""Roofline-term extraction from AOT-compiled artifacts.

Hardware model (TPU v5e-like, per chip):
  197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI.

cost_analysis() supplies per-device HLO FLOPs and bytes.  Collective bytes
are parsed from the compiled (SPMD, per-device) HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take the *operand* size (result size normalized by the group factor where
the op changes shape) — i.e. bytes each device injects into the ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_op.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        result_bytes = _type_bytes(m.group("type"))
        g = _group_size(line)
        if op == "all-gather":
            operand = result_bytes / g          # result is g× the operand
        elif op == "reduce-scatter":
            operand = result_bytes * g          # operand is g× the result
        else:                                   # all-reduce / a2a / permute
            operand = result_bytes
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0.0) + operand
        st.count_by_op[op] = st.count_by_op.get(op, 0) + 1
    return st


def roofline_terms(hlo_flops: float, hlo_bytes: float, coll_bytes: float,
                   n_chips: int, model_flops_global: float) -> dict:
    compute_t = hlo_flops / PEAK_FLOPS
    memory_t = hlo_bytes / HBM_BW
    coll_t = coll_bytes / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful_t = model_flops_global / (n_chips * PEAK_FLOPS)
    return {
        "compute_term_s": compute_t,
        "memory_term_s": memory_t,
        "collective_term_s": coll_t,
        "dominant": dominant,
        "step_bound_s": bound,
        "model_flops_global": model_flops_global,
        "hlo_flops_global": hlo_flops * n_chips,
        "useful_flops_ratio": (model_flops_global / (hlo_flops * n_chips)
                               if hlo_flops else 0.0),
        "roofline_fraction": useful_t / bound if bound else 0.0,
    }
