"""Trace-ingestion CLI — inspect / summarize / convert Accel-sim SASS
trace subset files (sim/traceio.py) without running the simulator.

  python -m repro.launch.trace_ingest inspect  FILE        # parsed view
  python -m repro.launch.trace_ingest summarize FILE|DIR   # ingest JSON
  python -m repro.launch.trace_ingest convert  FILE [-o OUT.json]
  python -m repro.launch.trace_ingest roundtrip FILE       # conformance

``inspect`` prints each kernel's launch shape and lowered class
histogram; ``summarize`` emits the ``TraceIngest`` JSON (fit-error
stats, dropped ops, divergent warps) for one file or every ``*.trace``
in a directory; ``convert`` dumps the lowered ``KernelTrace`` IR as
JSON (the exact arrays the batched frontend consumes); ``roundtrip``
re-synthesizes the lowered IR back to subset text, re-ingests it, and
verifies the IR is reproduced bit-exactly — the same property the
conformance suite pins (tests/test_traceio.py).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.sim import traceio


def cmd_inspect(args) -> int:
    for path in traceio.trace_files(args.path):
        for pk in traceio.parse_trace_file(path):
            kt, fit = traceio.lower_kernel(pk)
            print(f"kernel {pk.name!r}  grid={pk.grid} block={pk.block} "
                  f"shmem={pk.shmem}")
            print(f"  -> n_ctas={kt.n_ctas} warps_per_cta="
                  f"{kt.warps_per_cta} n_instr={kt.n_instr}")
            print(f"  classes: {traceio.class_histogram(kt)}")
            print(f"  dep chain: {int(kt.dep.sum())}/{kt.n_instr} "
                  f"dependent;  mem ops fitted: {fit.n_mem} "
                  f"(err mean={fit.fit_err_mean:.3f} "
                  f"max={fit.fit_err_max:.3f} blocks)")
            if fit.dropped:
                print(f"  dropped: {fit.dropped}")
            if fit.divergent_warps:
                print(f"  divergent warps (excluded from fit): "
                      f"{fit.divergent_warps}/{fit.n_warps_seen}")
    return 0


def cmd_summarize(args) -> int:
    out = [ing.summary() for ing in traceio.load_traces(args.path)]
    print(json.dumps(out if len(out) > 1 else out[0], indent=1))
    return 0


def cmd_convert(args) -> int:
    ing = traceio.load_trace(args.path)
    payload = {
        "name": ing.workload.name,
        "kernels": [{
            "name": k.name, "n_ctas": k.n_ctas,
            "warps_per_cta": k.warps_per_cta,
            "ops": k.ops.tolist(), "dep": k.dep.tolist(),
            "addr_mode": k.addr_mode.tolist(),
            "addr_param": k.addr_param.tolist(),
        } for k in ing.workload.kernels],
        "ingest": ing.summary(),
    }
    text = json.dumps(payload, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"[trace_ingest] wrote {args.out}")
    else:
        print(text)
    return 0


def cmd_roundtrip(args) -> int:
    ing = traceio.load_trace(args.path)
    text = traceio.synthesize_trace(ing.workload)
    parsed = traceio.parse_trace_text(text, path="<synthesized>")
    ok = True
    for pk, orig in zip(parsed, ing.workload.kernels):
        kt, _ = traceio.lower_kernel(pk)
        if kt != orig:
            ok = False
            print(f"[trace_ingest] ROUNDTRIP MISMATCH in kernel "
                  f"{orig.name!r}", file=sys.stderr)
    if len(parsed) != len(ing.workload.kernels):
        ok = False
    print(f"[trace_ingest] roundtrip "
          f"{'OK' if ok else 'FAILED'}: {len(parsed)} kernel(s)")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Accel-sim SASS trace subset tooling (sim/traceio.py)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, fn, with_out in (("inspect", cmd_inspect, False),
                               ("summarize", cmd_summarize, False),
                               ("convert", cmd_convert, True),
                               ("roundtrip", cmd_roundtrip, False)):
        p = sub.add_parser(name)
        p.add_argument("path", help=".trace file (or directory for "
                                    "inspect/summarize)")
        if with_out:
            p.add_argument("-o", "--out", default="",
                           help="write JSON here instead of stdout")
        p.set_defaults(fn=fn)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
