"""Training launcher: data pipeline + train_step + checkpoint/restart.

CPU-friendly by default (reduced config, no mesh); pass --mesh single/multi
to run the production-sharded step (requires forced host devices).  Designed
for SLURM-style preemption: on restart with the same --ckpt dir it resumes
from the latest checkpoint and replays the deterministic pipeline.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ShapeSpec, get_config, get_reduced
from repro.checkpointing.checkpoint import (AsyncSaver, latest_step, restore,
                                            save)
from repro.data.pipeline import DataConfig, Pipeline, make_batch_np
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.parallelism.ctx import NULL_CTX
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    if args.mesh == "none":
        ctx = NULL_CTX
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = make_ctx(mesh)
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=5,
                        total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                             max_seq=args.seq)
    start = 0
    if args.ckpt:
        ls = latest_step(args.ckpt)
        if ls is not None:
            state = restore(args.ckpt, ls, state)
            start = ls
            print(f"[train] resumed from step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, ctx))
    saver = AsyncSaver()
    pipe = Pipeline(cfg, shape, DataConfig(), start_step=start)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            saver.save_async(args.ckpt, step + 1, state)
    saver.wait()
    pipe.close()
    print(f"[train] done: {args.steps - start} steps, "
          f"final loss {float(metrics['loss']):.4f}")
    return state


if __name__ == "__main__":
    main()
