"""Design-space-exploration launcher — N GPU configs, ONE compiled program.

  python -m repro.launch.dse --n 8 --workload hotspot --scale 0.02
  python -m repro.launch.dse --base 3080ti --axis dram_row_penalty \\
      --values 8,16,24,48
  python -m repro.launch.dse --n 8 --sample-lat fp32 2 8 --check
  python -m repro.launch.dse --n 8 --check     # verify vs solo runs
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.dse --n 8 --mesh 2 2 --check

``--mesh A B`` shards the config lanes over a 2-D ('cfg', 'sm') device
mesh (core/distribute.py) — A cfg-devices × B sm-devices, A×B devices
total (on CPU, force them with XLA_FLAGS before jax initializes).

``--sample-lat CLASS LO HI`` (repeatable; likewise ``--sample-disp``)
sweeps a PER-CLASS entry of the typed DynConfig's timing tables: the N
lanes step the result latency (or dispatch interval) of instruction class
CLASS (fp32/int32/sfu/tensor/ldg/stg/bar) evenly from LO to HI — the
table leaves are traced, so the whole per-class sweep is still one
compiled program.  The ldg latency entry is inert (load latency is
cache-dependent: see sim/config.py:CoreDyn).

Without --axis/--sample-*, a default grid is swept: L2 latency × scheduler
(GTO/LRR), the two knobs with the clearest IPC signal on the paper's
benchmarks.  All lanes share one StaticConfig shape — only traced timing
parameters and the scheduler selector differ, which is what makes the
whole sweep a single ``jit(vmap(engine))`` call (core/sweep.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.core import stats as S
from repro.core import telemetry as T
from repro.core.engine import run_workload
from repro.core.parallel import make_sm_runner
from repro.core.sweep import sweep
from repro.launch.cli import (add_plan_args, add_sample_args,
                              add_search_args, plan_from_args, profile_ctx)
from repro.sim.config import (DYNAMIC_FIELDS, RTX3080TI, TINY, GPUConfig,
                              class_index, split_config)
from repro.sim.state import init_state
from repro.workloads import make_workload

BASES = {"tiny": TINY, "3080ti": RTX3080TI}


def default_grid(base: GPUConfig, n: int) -> list:
    """n configs: alternate GTO/LRR while stepping L2 latency."""
    out = []
    for i in range(n):
        out.append(dataclasses.replace(
            base,
            l2_lat=base.l2_lat // 2 + (i // 2) * base.l2_lat // 2,
            scheduler="gto" if i % 2 == 0 else "lrr"))
    return out


def axis_grid(base: GPUConfig, axis: str, values: list) -> list:
    if axis == "scheduler":
        return [dataclasses.replace(base, scheduler=v) for v in values]
    if axis not in DYNAMIC_FIELDS:
        raise SystemExit(f"--axis must be one of {DYNAMIC_FIELDS} or "
                         f"'scheduler', got {axis!r}")
    return [dataclasses.replace(base, **{axis: int(v)}) for v in values]


def sample_table_grid(base: GPUConfig, n: int, sample_lat=(),
                      sample_disp=(), seed: int = None) -> list:
    """n configs sampling per-class table entries over [lo, hi].

    ``sample_lat`` / ``sample_disp``: sequences of (class_name, lo, hi)
    triples; several triples vary jointly across the same n lanes.
    Default: lane i gets entry = round(lo + i/(n-1) * (hi-lo)) —
    deterministic linear steps, endpoints included.  With ``seed`` each
    lane instead draws every sampled entry uniformly from [lo, hi]
    (PCG64: same seed, same lanes — the randomized-probe complement to
    the linear sweep, shared by both launchers via --sample-seed)."""
    rng = (np.random.Generator(np.random.PCG64(seed))
           if seed is not None else None)
    out = []
    for i in range(n):
        frac = i / max(n - 1, 1)
        lat = list(base.lat_of_class)
        disp = list(base.disp_of_class)
        for table, samples in ((lat, sample_lat), (disp, sample_disp)):
            for cls, lo, hi in samples:
                lo, hi = int(lo), int(hi)
                table[class_index(str(cls))] = (
                    int(rng.integers(lo, hi + 1)) if rng is not None
                    else round(lo + frac * (hi - lo)))
        out.append(dataclasses.replace(base, lat_of_class=tuple(lat),
                                       disp_of_class=tuple(disp)))
    return out


def describe(cfg: GPUConfig) -> dict:
    d = {k: getattr(cfg, k) for k in DYNAMIC_FIELDS}
    d["scheduler"] = cfg.scheduler
    # always present so every row of a sweep has the same keys (a sampled
    # lane can land exactly on the default table)
    d["lat"] = list(cfg.lat_of_class)
    d["disp"] = list(cfg.disp_of_class)
    return d


def _solo_checker(scfg, w, max_cycles):
    """One compiled UNBATCHED program that replays any lane solo: dyn is
    a traced argument, so all the solo runs share a single compilation."""
    packed = [k.pack() for k in w.kernels]
    runner = make_sm_runner(scfg, "vmap")
    return jax.jit(lambda dyn: run_workload(
        init_state(scfg), packed, scfg, dyn, runner, max_cycles))


def run_search(args, plan, base, w):
    """--search: analytic-prune search instead of a fixed-grid sweep."""
    from repro.core import analytic
    from repro.core.search import SearchSpace, search

    space = SearchSpace.from_base(base, spread=args.search_spread,
                                  sample_lat=args.sample_lat,
                                  sample_disp=args.sample_disp)
    t0 = time.time()
    with profile_ctx(args):
        result = search(w, space, plan=plan,
                        n_candidates=args.search_cands,
                        calibrate_from=None if args.no_manifest else "",
                        log=print)
    wall = time.time() - t0

    rep = result.report()
    print(json.dumps(rep, indent=1))
    print(f"[dse] search {w.name}: scored {result.n_scored} candidates "
          f"analytically, verified {result.n_verified} cycle-accurately "
          f"over {len(result.rounds)} rounds, best={result.best_cycles} "
          f"cycles, wall={wall:.1f}s")

    if not args.no_manifest:
        # verified lanes + stats + the workload's feature vector: exactly
        # the rows calibration_rows_from_manifests harvests to warm-start
        # the next search of this StaticConfig
        mpath = T.write_manifest(
            "search", scfg=result.scfg, mesh_shape=args.mesh,
            timings={"wall_s": round(wall, 4)},
            stats=[st for _, _, st in result.verified],
            lanes=[analytic.describe_vec(v) for v, _, _ in result.verified],
            extra={"workload": w.name, "plan": plan.describe(),
                   "features": result.features.tolist(),
                   "search": rep, "profile_dir": args.profile or None})
        print(f"[dse] manifest: {mpath}")

    if args.check:
        solo_run = _solo_checker(result.scfg, w, args.max_cycles)
        for i, (vec, _, st) in enumerate(result.verified):
            dyn = split_config(result.scfg, analytic.decode(vec))[1]
            solo = S.comparable(S.finalize(solo_run(dyn)))
            lane = S.comparable(st)
            assert lane == solo, (i, lane, solo)
        print(f"[dse] check OK: all {result.n_verified} verified lanes "
              "bit-exact vs solo")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", choices=sorted(BASES), default="tiny")
    ap.add_argument("--workload", default="hotspot")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--axis", default="",
                    help="sweep one config field instead of the default grid")
    ap.add_argument("--values", default="",
                    help="comma-separated values for --axis")
    ap.add_argument("--check", action="store_true",
                    help="verify every lane against a solo engine run")
    add_sample_args(ap, when="the N lanes")
    add_search_args(ap)
    add_plan_args(ap)
    args = ap.parse_args(argv)
    plan = plan_from_args(args)

    base = BASES[args.base]
    if args.search:
        if args.axis:
            raise SystemExit("--search and --axis are separate modes; "
                             "pick one (--sample-* triples shape the "
                             "search box instead)")
        w = make_workload(args.workload, scale=args.scale)
        return run_search(args, plan, base, w)
    if args.axis and (args.sample_lat or args.sample_disp):
        raise SystemExit("--axis and --sample-lat/--sample-disp are "
                         "separate sweep modes; pick one")
    if args.axis:
        values = [v for v in args.values.split(",") if v]
        if not values:
            raise SystemExit("--axis needs --values v1,v2,...")
        cfgs = axis_grid(base, args.axis, values)
    elif args.sample_lat or args.sample_disp:
        cfgs = sample_table_grid(base, args.n, args.sample_lat,
                                 args.sample_disp, seed=args.sample_seed)
    else:
        cfgs = default_grid(base, args.n)

    w = make_workload(args.workload, scale=args.scale)
    t0 = time.time()
    with profile_ctx(args):
        result = sweep(w, cfgs, plan=plan)
    wall = time.time() - t0

    rows = []
    for cfg, st in zip(cfgs, result.stats):
        rows.append(dict(describe(cfg), cycles=st["cycles"], ipc=st["ipc"],
                         l1_miss=st["l1_miss"], l2_miss=st["l2_miss"],
                         dram_req=st["dram_req"]))
    print(json.dumps(rows, indent=1))
    where = (f"{args.mesh[0]}x{args.mesh[1]} ('cfg','sm') mesh"
             if args.mesh else "one device")
    tm = result.timings
    print(f"[dse] {len(cfgs)} configs × {w.name}: one compiled call on "
          f"{where}, wall={wall:.1f}s "
          f"(compile={tm.get('compile_s')}s execute={tm.get('execute_s')}s "
          f"{tm.get('lanes_per_s')} lanes/s)")

    if not args.no_manifest:
        tls = result.timelines()
        mpath = T.write_manifest(
            "dse", scfg=result.scfg, mesh_shape=args.mesh,
            timings=dict(tm, wall_s=round(wall, 4)),
            stats=result.stats,
            timelines={k: v.tolist() for k, v in tls.items()} or None,
            lanes=[describe(c) for c in cfgs],
            extra={"workload": w.name, "plan": plan.describe(),
                   "profile_dir": args.profile or None})
        print(f"[dse] manifest: {mpath}")

    if args.check:
        solo_run = _solo_checker(result.scfg, w, args.max_cycles)
        for i, cfg in enumerate(cfgs):
            solo = S.comparable(S.finalize(solo_run(split_config(cfg)[1])))
            lane = S.comparable(result.stats[i])
            assert lane == solo, (i, lane, solo)
        print(f"[dse] check OK: all {len(cfgs)} lanes bit-exact vs solo")


if __name__ == "__main__":
    main()
