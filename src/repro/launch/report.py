"""Run-manifest report CLI — inspect what a run did before touching code.

  python -m repro.launch.report list [DIR]
  python -m repro.launch.report summarize MANIFEST
  python -m repro.launch.report timeline MANIFEST [--lane KEY]
      [--counters issued,l1_miss,...] [--csv] [--cumulative] [--width N]
  python -m repro.launch.report diff A B [--strict]

``summarize`` prints a manifest's provenance (git sha, StaticConfig hash,
host/device context, mesh shape), the compile-vs-execute wall-clock split
and lanes/sec, and a per-lane stat table.

``timeline`` renders the sampled counter timelines (core/telemetry.py) as
ASCII sparklines — per-sample *deltas* by default, so a burst of L1
misses or a stretch of pure lockstep waste is visible at a glance —
or as CSV rows for downstream tooling.  When the manifest carries final
stats it also verifies the telemetry invariant: the last sample of every
cumulative counter must equal the ``finalize()`` total (exit 1 if not).

``diff`` compares two runs' ``comparable()`` stats lane-by-lane — the
first tool to reach for when a perf change might have shifted simulation
semantics (it must NOT: lanes are bit-exact across execution modes).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.stats import comparable
from repro.core.telemetry import COUNTERS, FINAL_MATCH, runs_dir

BLOCKS = "▁▂▃▄▅▆▇█"


def load(path: str) -> dict:
    with open(path) as f:
        m = json.load(f)
    if not isinstance(m, dict) or "kind" not in m:
        raise SystemExit(f"{path}: not a run manifest")
    return m


def spark(vals, width: int = 64) -> str:
    """ASCII sparkline of a numeric series, resampled to ``width``."""
    if not vals:
        return ""
    if len(vals) > width:                      # downsample by striding
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = max(hi - lo, 1)
    return "".join(BLOCKS[int((v - lo) * (len(BLOCKS) - 1) / span)]
                   for v in vals)


def _deltas(series):
    return [series[0]] + [b - a for a, b in zip(series, series[1:])]


def _lane_stats(manifest: dict):
    return manifest.get("stats") or []


def _timelines(manifest: dict) -> dict:
    return manifest.get("timelines") or {}


def _counter_names(manifest: dict) -> list:
    tel = manifest.get("telemetry") or {}
    return list(tel.get("counters") or COUNTERS)


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------

def cmd_list(args) -> int:
    d = args.dir or runs_dir()
    if not os.path.isdir(d):
        print(f"(no runs dir at {d})")
        return 0
    names = sorted(n for n in os.listdir(d) if n.endswith(".json"))
    for n in names:
        try:
            m = load(os.path.join(d, n))
        except (SystemExit, json.JSONDecodeError):
            continue
        t = m.get("timings") or {}
        print(f"{n}  kind={m['kind']}  sha={m.get('git_sha', '?')[:10]}  "
              f"lanes={t.get('n_lanes', '?')}  "
              f"lanes/s={t.get('lanes_per_s', '?')}")
    if not names:
        print(f"(no manifests under {d})")
    return 0


def cmd_summarize(args) -> int:
    m = load(args.manifest)
    host = m.get("host") or {}
    t = m.get("timings") or {}
    print(f"kind:        {m['kind']}")
    print(f"created:     {m.get('created_utc')}")
    print(f"git sha:     {m.get('git_sha')}")
    print(f"static cfg:  {m.get('static_config_hash')}")
    print(f"host:        {host.get('hostname')} "
          f"({host.get('device_platform')}:{host.get('device_kind')} "
          f"x{host.get('device_count')})")
    if host.get("xla_flags"):
        print(f"xla_flags:   {host['xla_flags']}")
    print(f"mesh:        {m.get('mesh_shape') or 'single device'}")
    print(f"timings:     compile={t.get('compile_s')}s "
          f"execute={t.get('execute_s')}s wall={t.get('wall_s')}s "
          f"lanes={t.get('n_lanes')} lanes/s={t.get('lanes_per_s')}")
    tel = m.get("telemetry") or {}
    if tel.get("samples"):
        print(f"telemetry:   {tel['samples']} samples "
              f"every {tel['every']} quanta, "
              f"{len(tel.get('counters', []))} counters")
    stats = _lane_stats(m)
    if stats:
        print(f"lanes ({len(stats)}):")
        keys = ("cycles", "ipc", "issued", "l1_miss", "l2_miss", "dram_req",
                "lockstep_waste")
        hdr = [k for k in keys if any(k in s for s in stats)]
        print("  lane  " + "  ".join(f"{k:>14}" for k in hdr))
        for i, s in enumerate(stats):
            label = s.get("workload", str(i))
            if "cfg" in s:
                label = f"{label}/{s['cfg']}"
            print(f"  {label:<12}" + "  ".join(
                f"{s.get(k, '-'):>14}" for k in hdr))
    return 0


def render_timeline(manifest: dict, lane: str = "", counters=None,
                    csv: bool = False, cumulative: bool = False,
                    width: int = 64, out=sys.stdout) -> int:
    """Render timelines; returns the number of final-sample/finalize
    mismatches found (0 = invariant holds or not verifiable)."""
    names = _counter_names(manifest)
    tls = _timelines(manifest)
    if not tls:
        print("manifest has no timelines (run with --telemetry S)",
              file=out)
        return 0
    stats = _lane_stats(manifest)
    sel = counters or [c for c in names if c != "cycle"]
    unknown = sorted(set(sel) - set(names))
    if unknown:
        raise SystemExit(f"unknown counter(s) {unknown}; "
                         f"manifest has {names}")
    mismatches = 0
    for li, (key, rows) in enumerate(tls.items()):
        if lane and key != lane:
            continue
        if csv:
            print("lane,sample," + ",".join(names), file=out)
            for si, row in enumerate(rows):
                print(f"{key},{si}," + ",".join(str(v) for v in row),
                      file=out)
            continue
        print(f"lane {key}: {len(rows)} samples", file=out)
        cyc = [r[names.index("cycle")] for r in rows]
        if cyc:
            print(f"  {'cycle':>14} {cyc[0]} .. {cyc[-1]}", file=out)
        for cname in sel:
            ci = names.index(cname)
            series = [r[ci] for r in rows]
            shown = series if cumulative else _deltas(series)
            print(f"  {cname:>14} {spark(shown, width)}  "
                  f"final={series[-1] if series else '-'}", file=out)
        # verify: last sample of every cumulative counter == finalize total
        if li < len(stats) and rows:
            last = rows[-1]
            bad = [c for c in FINAL_MATCH
                   if c in names and c in stats[li]
                   and last[names.index(c)] != stats[li][c]]
            if bad:
                mismatches += len(bad)
                print(f"  MISMATCH vs finalize(): {bad}", file=out)
            else:
                print("  final sample == finalize() totals ✓", file=out)
    return mismatches


def cmd_timeline(args) -> int:
    m = load(args.manifest)
    counters = ([c for c in args.counters.split(",") if c]
                if args.counters else None)
    bad = render_timeline(m, lane=args.lane, counters=counters,
                          csv=args.csv, cumulative=args.cumulative,
                          width=args.width)
    return 1 if bad else 0


def diff_stats(a: dict, b: dict) -> list:
    """[(lane_key, counter, a_val, b_val)] over the comparable() subset of
    two manifests' per-lane stats, lanes matched by (workload, cfg) when
    labeled, by position otherwise."""
    def lane_map(m):
        out = {}
        for i, s in enumerate(_lane_stats(m)):
            key = (s.get("workload", ""), s.get("cfg", i))
            out[key if key != ("", i) else i] = s
        return out

    la, lb = lane_map(a), lane_map(b)
    diffs = []
    for key in la:
        if key not in lb:
            diffs.append((str(key), "<lane missing in B>", "-", "-"))
            continue
        sa, sb = la[key], lb[key]
        try:
            ca, cb = comparable(sa), comparable(sb)
        except KeyError:            # partial stats: fall back to shared keys
            shared = sorted(set(sa) & set(sb))
            ca = {k: sa[k] for k in shared}
            cb = {k: sb[k] for k in shared}
        for k in ca:
            if ca[k] != cb.get(k):
                diffs.append((str(key), k, ca[k], cb.get(k)))
    for key in lb:
        if key not in la:
            diffs.append((str(key), "<lane missing in A>", "-", "-"))
    return diffs


def cmd_diff(args) -> int:
    a, b = load(args.a), load(args.b)
    ta = (a.get("timings") or {})
    tb = (b.get("timings") or {})
    print(f"A: {os.path.basename(args.a)} sha={a.get('git_sha', '?')[:10]} "
          f"lanes/s={ta.get('lanes_per_s')}")
    print(f"B: {os.path.basename(args.b)} sha={b.get('git_sha', '?')[:10]} "
          f"lanes/s={tb.get('lanes_per_s')}")
    if ta.get("lanes_per_s") and tb.get("lanes_per_s"):
        r = tb["lanes_per_s"] / max(ta["lanes_per_s"], 1e-9)
        print(f"throughput:  B/A = {r:.2f}x")
    diffs = diff_stats(a, b)
    if not diffs:
        print("stats: IDENTICAL on the comparable() subset")
        return 0
    print(f"stats: {len(diffs)} comparable() difference(s):")
    for lane, key, va, vb in diffs:
        print(f"  lane {lane:<16} {key:<14} A={va} B={vb}")
    return 1 if args.strict else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.report")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="list manifests in a runs dir")
    p.add_argument("dir", nargs="?", default="")

    p = sub.add_parser("summarize", help="one-screen manifest summary")
    p.add_argument("manifest")

    p = sub.add_parser("timeline",
                       help="render sampled counter timelines")
    p.add_argument("manifest")
    p.add_argument("--lane", default="",
                   help="render one lane only (key as shown in the "
                        "manifest: '0', 'mixed/1', ...)")
    p.add_argument("--counters", default="",
                   help="comma-separated counter subset")
    p.add_argument("--csv", action="store_true",
                   help="emit CSV rows instead of sparklines")
    p.add_argument("--cumulative", action="store_true",
                   help="plot cumulative values instead of per-sample "
                        "deltas")
    p.add_argument("--width", type=int, default=64)

    p = sub.add_parser("diff", help="diff two runs' comparable() stats")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 when stats differ")

    args = ap.parse_args(argv)
    return {"list": cmd_list, "summarize": cmd_summarize,
            "timeline": cmd_timeline, "diff": cmd_diff}[args.cmd](args)


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:     # e.g. `report timeline --csv | head`
        sys.exit(0)
