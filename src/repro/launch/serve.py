"""Serving launcher: batched prefill + greedy decode loop."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config, get_reduced
from repro.models import factory
from repro.parallelism.ctx import NULL_CTX


def generate(params, cfg, prompts, *, max_new: int = 16, ctx=NULL_CTX):
    """prompts: (B, S) int32. Greedy decode max_new tokens."""
    b, s = prompts.shape
    logits, cache = factory.prefill(params, {"tokens": prompts}, cfg=cfg,
                                    ctx=ctx, max_len=s + max_new)
    decode = jax.jit(lambda p, c, t: factory.decode(p, c, {"tokens": t},
                                                    cfg=cfg, ctx=ctx))
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    for _ in range(max_new - 1):
        logits, cache = decode(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    return jnp.concatenate(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = factory.init_params(key, cfg,
                                 max_seq=args.prompt_len + args.max_new)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    t0 = time.time()
    out = generate(params, cfg, prompts, max_new=args.max_new)
    dt = time.time() - t0
    print(f"[serve] generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[0])


if __name__ == "__main__":
    main()
