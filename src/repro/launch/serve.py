"""Simulation server frontend: line-JSON over stdin or a TCP socket.

The transport half of simulation-as-a-service (core/service.py holds the
queue/admission/batch-former/result-router).  One warm process serves
every client's jobs: submissions are continuously packed into pair lanes
so unrelated requests share compiled programs, the in-process AOT
executable cache, and (with --cache-dir) jax's persistent compile cache.

Protocol (one JSON object per line, documented in benchmarks/README.md):

  → {"op": "submit", "id"?: str, "workload": "mixed" | "trace:vecadd",
     "scale"?: float, "config"?: {...} | "configs": [{...}] |
     "sample": {"n": 4, "lat": [["fp32", 2, 8]], "seed"?: int}}
    (or "trace_text": "<SASS trace text>" instead of "workload";
     a line with no "op" is treated as a submit)
  ← {"ok": true, "id": ..., "status": "queued", "lanes": N}  on admission
  ← {"ok": false, "error": ..., "field": ...}                on rejection
  ← {"ok": true, "id": ..., "status": "done", "stats": [...],
     "latency": {"queue_s", "compile_s", "execute_s", "total_s"}, ...}
    streamed whenever the job's batch completes (order ≠ submit order)

  → {"op": "flush"}     run the queue now, deadline or not
  → {"op": "stats"}     ← server counters (jobs/batches/AOT hits/pending)
  → {"op": "shutdown"}  drain, then exit

``--selftest`` runs the in-process conformance smoke (mixed zoo + trace
jobs bit-identical to solo runs; warm resubmission hits the AOT cache)
and exits nonzero on any mismatch — the tier-1 CI entry point.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

from repro.launch.cli import (add_plan_args, add_service_args,
                              plan_from_args, service_from_args)


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="persistent simulation server (line-JSON protocol)")
    add_service_args(ap)
    add_plan_args(ap)
    # A server co-batches heterogeneous jobs, so same-footprint grouping
    # is the sensible default here (zoo/dse keep bucket_by="none").
    ap.set_defaults(bucket_by="shape")
    ap.add_argument("--stdin", action="store_true",
                    help="serve the line-JSON protocol on stdin/stdout "
                         "(default when no --port)")
    ap.add_argument("--port", type=int, default=None,
                    help="serve the line-JSON protocol on a TCP socket")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--selftest", action="store_true",
                    help="run the in-process conformance smoke and exit")
    return ap.parse_args(argv)


def handle_line(svc, line: str, reply) -> bool:
    """Dispatch one protocol line; ``reply(dict)`` sends a response.
    Returns False when the client asked the server to shut down."""
    from repro.core.service import ServiceError

    line = line.strip()
    if not line:
        return True
    try:
        payload = json.loads(line)
    except json.JSONDecodeError as e:
        reply({"ok": False, "error": f"invalid JSON: {e}"})
        return True
    op = payload.get("op", "submit") if isinstance(payload, dict) \
        else "submit"
    if op == "submit":
        try:
            job = svc.submit(payload)
        except ServiceError as e:
            reply({"ok": False, "error": str(e), "field": e.field})
            return True
        reply({"ok": True, "id": job.id, "job": job.seq,
               "status": "queued", "lanes": job.n_lanes})
    elif op == "flush":
        svc.flush()
        reply({"ok": True, "status": "flushed"})
    elif op == "stats":
        reply(dict({"ok": True}, **svc.stats()))
    elif op == "shutdown":
        reply({"ok": True, "status": "draining"})
        return False
    else:
        reply({"ok": False, "error": f"unknown op {op!r}", "field": "op"})
    return True


def serve_stdin(svc) -> None:
    """The line-JSON protocol over stdin/stdout.  Completions stream on
    stdout interleaved with acks (every line is a self-contained JSON
    object, so clients key on "status")."""
    lock = threading.Lock()

    def reply(obj):
        with lock:
            sys.stdout.write(json.dumps(obj) + "\n")
            sys.stdout.flush()

    svc.on_done = lambda job: reply(job.response())
    for line in sys.stdin:
        if not handle_line(svc, line, reply):
            break
    svc.shutdown(drain=True)


def serve_socket(svc, host: str, port: int) -> None:
    """The same protocol over TCP: one thread per connection, and each
    job's completion routes back to the connection that submitted it."""
    import socket
    import socketserver

    routes: dict = {}          # job seq -> that connection's reply fn
    routes_lock = threading.Lock()

    def on_done(job):
        with routes_lock:
            reply = routes.pop(job.seq, None)
        if reply is not None:
            reply(job.response())
    svc.on_done = on_done

    stop = threading.Event()

    class Handler(socketserver.StreamRequestHandler):
        def handle(self):
            wlock = threading.Lock()

            def reply(obj):
                with wlock:
                    try:
                        self.wfile.write((json.dumps(obj) + "\n").encode())
                        self.wfile.flush()
                    except OSError:
                        pass       # client went away; drop the response

            def track(obj):
                if obj.get("status") == "queued":
                    with routes_lock:
                        routes[obj["job"]] = reply
                reply(obj)

            for raw in self.rfile:
                if not handle_line(svc, raw.decode("utf-8", "replace"),
                                   track):
                    stop.set()
                    return

    class Server(socketserver.ThreadingTCPServer):
        allow_reuse_address = True
        daemon_threads = True

    with Server((host, port), Handler) as srv:
        print(f"[serve] listening on {host}:{srv.server_address[1]} "
              f"(n_sm={svc.base.n_sm}, batch_lanes={svc.batch_lanes})",
              file=sys.stderr, flush=True)
        t = threading.Thread(target=srv.serve_forever, daemon=True)
        t.start()
        try:
            stop.wait()
        except KeyboardInterrupt:
            pass
        srv.shutdown()
    svc.shutdown(drain=True)


# ---------------------------------------------------------------------------
# --selftest: the conformance smoke CI runs (tier-1)
# ---------------------------------------------------------------------------

def selftest() -> int:
    """Mixed zoo + trace jobs through a synchronous server, checked
    bit-identical to solo ``simulate()`` runs; then the same jobs again
    to prove the warm path (compile_s == 0.0 AOT hits); then admission
    and validation rejections by field name."""
    from repro.core import stats as S
    from repro.core.engine import simulate
    from repro.core.parallel import make_sm_runner
    from repro.core.plan import RunPlan
    from repro.core.service import ServiceError, SimService
    from repro.sim.config import TINY

    max_cycles = 1 << 15
    svc = SimService(base=TINY,
                     plan=RunPlan(max_cycles=max_cycles, bucket_by="shape"),
                     start=False)
    subs = [
        {"id": "a", "workload": "mixed", "scale": 0.02},
        {"id": "b", "workload": "reduction_tree", "scale": 0.02,
         "config": {"l2_lat": 64, "scheduler": "lrr"}},
        {"id": "c", "workload": "trace:vecadd"},
        {"id": "d", "workload": "streaming_copy", "scale": 0.02,
         "sample": {"n": 2, "lat": [["fp32", 2, 8]]}},
    ]
    jobs = [svc.submit(s) for s in subs]
    served = svc.run_pending()
    assert served == len(jobs), f"served {served}/{len(jobs)}"

    def sig(st):
        return dict(S.comparable(st), timeouts=st["timeouts"])

    checked = 0
    for job in jobs:
        assert job.done and job.error is None, job.response()
        for (w, cfg), st in zip(job.pairs, job.stats):
            solo = simulate(w, cfg, make_sm_runner(cfg, "vmap"),
                            plan=RunPlan(max_cycles=max_cycles))
            assert sig(st) == sig(S.finalize(solo)), \
                f"lane mismatch for job {job.id} ({w.name})"
            checked += 1
    print(f"[selftest] {checked} served lanes bit-identical to solo runs")

    warm = [svc.submit(s) for s in subs]
    svc.run_pending()
    batch = warm[0].batch
    assert batch["compile_s"] == 0.0 and batch["aot_cache"] == "hit", batch
    print(f"[selftest] warm resubmission: compile_s={batch['compile_s']} "
          f"aot_cache={batch['aot_cache']}")

    for err_sub, want in [
        ({"workload": "no_such_workload"}, "workload"),
        ({"workload": "mixed", "config": {"n_sm": 99}}, "config.n_sm"),
        ({"workload": "mixed", "trace_text": "k x"}, "workload"),
        ({"trace_text": "this is not a trace"}, "trace_text"),
    ]:
        try:
            svc.submit(err_sub)
        except ServiceError as e:
            assert e.field == want or (e.field or "").startswith(want), \
                (err_sub, e.field, str(e))
        else:
            raise AssertionError(f"accepted bad submission {err_sub}")
    print("[selftest] malformed submissions rejected by field name")
    print(f"[selftest] PASS  counters={svc.stats()}")
    return 0


def main(argv=None):
    args = _parse_args(argv)
    if args.selftest:
        raise SystemExit(selftest())
    plan = plan_from_args(args)
    svc = service_from_args(args, plan)
    if args.port is not None:
        serve_socket(svc, args.host, args.port)
    else:
        serve_stdin(svc)
    print(f"[serve] done  {json.dumps(svc.stats())}", file=sys.stderr)


if __name__ == "__main__":
    main()
