"""Workload-zoo launcher — list the zoo, run one workload, or sweep a
whole benchmarks × configs grid as ONE compiled program.

  python -m repro.launch.zoo --list
  python -m repro.launch.zoo --run random_gather --scale 0.05
  python -m repro.launch.zoo --grid 4 4 --check     # W×C lanes vs solo
  python -m repro.launch.zoo --trace tests/data/traces --check
  python -m repro.launch.zoo --trace tests/data/traces --grid 3 4 --check
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.zoo --grid 4 4 --mesh 2 2 --check

``--trace FILE|DIR`` ingests real Accel-sim SASS trace subset files
(sim/traceio.py) and registers them in the zoo as ``trace:<stem>``
workloads.  With ``--grid W C`` the trace workloads fill the grid's
workload rows first (synthetic zoo names top up if W exceeds the trace
count) and ride the batched frontend unchanged; trace rows keep their
real CTA counts (``--scale`` applies to synthetic generators only).
Without ``--grid``/``--run`` an ingest summary is printed per trace, and
``--check`` additionally runs an (all traces × 2 configs) grid verifying
every lane bit-exact vs its solo run — the CI trace smoke.

``--grid W C`` takes the first W zoo workloads (registry order) and a
C-point config grid (launch/dse.py:default_grid — L2 latency × scheduler)
and runs the full grid in one ``jit(vmap(vmap(...)))`` call
(core/sweep.py:grid_sweep).  ``--check`` reruns every (workload, config)
pair solo and asserts the grid lane is bit-identical — including lanes
whose workload was padded with NOP slots / empty kernels (core/batch.py).

``--sample-lat CLASS LO HI`` / ``--sample-disp CLASS LO HI`` (repeatable)
replace the default config grid with a per-class timing-table sweep
(launch/dse.py:sample_table_grid): the C lanes step the result latency /
dispatch interval of instruction class CLASS evenly from LO to HI — the
typed DynConfig's table leaves are traced, so benchmarks × per-class
timing points still compile to one program.

``--mesh A B`` distributes the grid over a 2-D ('cfg', 'sm') device mesh
(core/distribute.py): config lanes sharded over A cfg-devices, each
lane's SM axis over B sm-devices.  Needs A×B devices — on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=<A*B>`` before jax
initializes.  ``--check`` still compares against single-device solo runs,
so it proves the distributed lanes bit-exact end to end.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import stats as S
from repro.core import telemetry as T
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.core.plan import RunPlan
from repro.core.sweep import grid_sweep
from repro.launch.cli import (add_plan_args, add_sample_args, plan_from_args,
                              profile_ctx)
from repro.launch.dse import (BASES, default_grid, describe,
                              sample_table_grid)
from repro.sim.workloads import (TRACE_INGESTS, register_traces, zoo_names,
                                 zoo_workload)


def run_trace_summary(args, trace_names) -> None:
    """Ingest-summary mode (``--trace`` without --grid/--run): report
    fit stats per trace; with --check, verify an (all traces × 2 cfgs)
    grid bit-exact against solo runs."""
    for name in trace_names:
        ing = TRACE_INGESTS[name]
        s = ing.summary()
        print(f"[zoo] ingested {name}: {s['n_kernels']} kernel(s), "
              f"{s['total_ctas']} CTAs, n_instr={s['n_instr']}, "
              f"fit_err mean={s['fit_err_mean']} max={s['fit_err_max']} "
              f"blocks")
    if args.check:
        workloads = [zoo_workload(n) for n in trace_names]
        cfgs = default_grid(BASES[args.base], 2)
        grid = grid_sweep(workloads, cfgs, plan=plan_from_args(args))
        check_grid_vs_solo(grid, workloads, cfgs, args.max_cycles)
        print(f"[zoo] check OK: {len(workloads)}x{len(cfgs)} trace grid "
              "bit-exact vs solo runs")


def lane_signature(stats: dict) -> dict:
    """What --check compares: the cross-mode-comparable stats plus the
    truncation counter (a grid lane must also time out exactly when its
    solo run does)."""
    return dict(S.comparable(stats), timeouts=stats["timeouts"])


def check_grid_vs_solo(grid, workloads, cfgs, max_cycles: int) -> int:
    """Re-run every (workload, config) pair solo and assert its grid
    lane is bit-identical.  The ONE --check oracle for both grid modes.
    Returns the verified lane count."""
    runner = make_sm_runner(grid.scfg, "vmap")
    solo_plan = RunPlan(max_cycles=max_cycles)   # the padded solo oracle
    for w, workload in enumerate(workloads):
        for c, cfg in enumerate(cfgs):
            solo = lane_signature(S.finalize(simulate(
                workload, cfg, runner, plan=solo_plan)))
            lane = lane_signature(grid.stats[w][c])
            assert lane == solo, (grid.names[w], c, lane, solo)
    return len(workloads) * len(cfgs)


def _scale_for(name: str, scale: float) -> float:
    """Trace-derived workloads keep their real CTA counts; --scale
    applies to the synthetic generators only."""
    return 1.0 if name.startswith("trace:") else scale


def run_grid(args, trace_names=()) -> None:
    n_w, n_c = args.grid
    names = list(trace_names) + [n for n in zoo_names()
                                 if n not in trace_names]
    if n_w > len(names):
        raise SystemExit(f"--grid {n_w} exceeds zoo size {len(names)}")
    base = BASES[args.base]
    workloads = [zoo_workload(n, scale=_scale_for(n, args.scale))
                 for n in names[:n_w]]
    if args.sample_lat or args.sample_disp:
        cfgs = sample_table_grid(base, n_c, args.sample_lat,
                                 args.sample_disp, seed=args.sample_seed)
    else:
        cfgs = default_grid(base, n_c)
    plan = plan_from_args(args)

    t0 = time.time()
    with profile_ctx(args):
        grid = grid_sweep(workloads, cfgs, plan=plan)
    wall = time.time() - t0
    print(json.dumps(grid.table(), indent=1))
    lanes = n_w * n_c
    where = (f"{args.mesh[0]}x{args.mesh[1]} ('cfg','sm') mesh"
             if args.mesh else "one device")
    tm = grid.timings
    print(f"[zoo] grid {n_w} workloads × {n_c} configs = {lanes} lanes "
          f"(bucket_by={plan.bucket_by} layout={plan.layout} "
          f"buckets={tm.get('n_buckets')}) on {where}, wall={wall:.1f}s "
          f"(compile={tm.get('compile_s')}s execute={tm.get('execute_s')}s "
          f"{tm.get('lanes_per_s')} lanes/s)")

    if not args.no_manifest:
        tls = grid.timelines()
        mpath = T.write_manifest(
            "zoo_grid", scfg=grid.scfg, mesh_shape=args.mesh,
            timings=dict(tm, wall_s=round(wall, 4)),
            stats=[dict(grid.stats[w][c], workload=grid.names[w], cfg=c)
                   for w in range(n_w) for c in range(n_c)],
            timelines={k: v.tolist() for k, v in tls.items()} or None,
            lanes=[dict(describe(cfg), workload=grid.names[w], cfg=c)
                   for w in range(n_w) for c, cfg in enumerate(cfgs)],
            extra={"workloads": grid.names, "plan": plan.describe(),
                   "profile_dir": args.profile or None})
        print(f"[zoo] manifest: {mpath}")

    if args.check:
        n = check_grid_vs_solo(grid, workloads, cfgs, args.max_cycles)
        print(f"[zoo] check OK: all {n} lanes bit-exact vs solo runs")


def run_one(args) -> None:
    w = zoo_workload(args.run, scale=_scale_for(args.run, args.scale))
    plan = plan_from_args(args)
    [cfg] = plan.apply_telemetry([BASES[args.base]])
    t0 = time.time()
    with profile_ctx(args):
        st = simulate(w, cfg, make_sm_runner(cfg, "vmap"), plan=plan)
    wall = time.time() - t0
    out = S.finalize(st)
    print(json.dumps(dict(S.comparable(out), ipc=out["ipc"],
                          timeouts=out["timeouts"]), indent=1))
    flag = " [TIMEOUT: truncated at max_cycles]" if out["timeout"] else ""
    print(f"[zoo] {w.name}: {out['cycles']} GPU cycles, ipc={out['ipc']}, "
          f"wall={wall:.1f}s{flag}")

    if not args.no_manifest:
        from repro.sim.config import split_config
        scfg, _ = split_config(cfg)
        tls = ({w.name: T.timeline(st).tolist()}
               if T.enabled(scfg) else None)
        mpath = T.write_manifest(
            "zoo_run", scfg=scfg,
            timings={"wall_s": round(wall, 4), "n_lanes": 1},
            stats=[dict(out, workload=w.name)], timelines=tls,
            lanes=[dict(describe(cfg), workload=w.name)],
            extra={"workloads": [w.name],
                   "profile_dir": args.profile or None})
        print(f"[zoo] manifest: {mpath}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="list zoo workload names")
    ap.add_argument("--run", default="", help="simulate one zoo workload")
    ap.add_argument("--grid", nargs=2, type=int, metavar=("W", "C"),
                    help="sweep first W workloads × C configs, one program")
    ap.add_argument("--trace", default="", metavar="FILE|DIR",
                    help="ingest Accel-sim SASS trace subset file(s) and "
                         "register them as trace:<stem> zoo workloads")
    ap.add_argument("--base", choices=sorted(BASES), default="tiny")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--check", action="store_true",
                    help="with --grid: verify every lane vs a solo run")
    add_sample_args(ap, when="--grid")
    add_plan_args(ap)
    args = ap.parse_args(argv)

    if (args.sample_lat or args.sample_disp) and not args.grid:
        raise SystemExit("--sample-lat/--sample-disp shape the config grid "
                         "and need --grid W C")
    trace_names = []
    if args.trace:
        trace_names = register_traces(args.trace)
    if args.list:
        for n in zoo_names():
            print(n)
    elif args.grid:
        run_grid(args, trace_names)
    elif args.run:
        run_one(args)
    elif trace_names:
        run_trace_summary(args, trace_names)
    else:
        raise SystemExit("pick one of --list / --run NAME / --grid W C / "
                         "--trace FILE|DIR")


if __name__ == "__main__":
    main()
