import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init.  (Overridable for fast local experiments.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces
  · compiled.memory_analysis()  — per-device bytes (proves it fits)
  · compiled.cost_analysis()    — per-device HLO FLOPs / bytes
  · collective bytes parsed from the compiled SPMD HLO
  · the three roofline terms (see launch/hlo_analysis.py)
and writes one JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch codeqwen1.5-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both-meshes]
"""
import argparse
import gc
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis as hlo
from repro.launch import hlo_costs
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.models import factory
from repro.parallelism import sharding as shd
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def _opt_config(cfg) -> OptConfig:
    big = cfg.param_count() > 1e11
    return OptConfig(moment_dtype="bfloat16" if big else "float32")


def _whisper_max_seq(shape) -> int:
    return shape.seq_len


def build_lowerable(arch: str, shape_name: str, *, multi_pod: bool,
                    dtype=jnp.bfloat16):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        return None, None, {"skipped": True,
                            "reason": cfg.skipped_cells()[0][1]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    n_chips = mesh.size

    def named(spec_tree):
        return shd.named(mesh, spec_tree)

    key = jax.random.PRNGKey(0)
    max_seq = shape.seq_len

    if shape.kind == "train":
        opt_cfg = _opt_config(cfg)
        state_shapes = jax.eval_shape(
            lambda: {
                "params": factory.init_params(key, cfg, dtype,
                                              max_seq=max_seq),
                "opt": init_opt_state(
                    factory.init_params(key, cfg, dtype, max_seq=max_seq),
                    opt_cfg),
                "step": jnp.zeros((), jnp.int32),
            })
        pspecs = shd.param_pspecs(state_shapes["params"], cfg, ctx)
        mspecs = shd.moments_pspecs(pspecs, state_shapes["params"], ctx)
        state_specs = {"params": pspecs,
                       "opt": {"m": mspecs, "v": mspecs},
                       "step": P()}
        batch_shapes = factory.batch_specs(cfg, shape, dtype)
        batch_specs = shd.batch_pspecs(batch_shapes, ctx)
        step = make_train_step(cfg, opt_cfg, ctx)
        metric_specs = {k: P() for k in
                        ("loss", "ce", "aux", "grad_norm")}
        fn = jax.jit(step,
                     in_shardings=(named(state_specs), named(batch_specs)),
                     out_shardings=(named(state_specs), named(metric_specs)),
                     donate_argnums=(0,))
        args = (state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        param_shapes = jax.eval_shape(
            lambda: factory.init_params(key, cfg, dtype, max_seq=max_seq))
        pspecs = shd.param_pspecs(param_shapes, cfg, ctx)
        batch_shapes = factory.batch_specs(cfg, shape, dtype)
        batch_specs = shd.batch_pspecs(batch_shapes, ctx)
        pf = partial(factory.prefill, cfg=cfg, ctx=ctx, max_len=shape.seq_len)
        out_shapes = jax.eval_shape(pf, param_shapes, batch_shapes)
        cache_specs = shd.cache_pspecs(out_shapes[1], cfg, ctx)
        lspec = shd.logits_pspec(cfg, ctx, shape.global_batch)
        fn = jax.jit(pf,
                     in_shardings=(named(pspecs), named(batch_specs)),
                     out_shardings=(named(lspec), named(cache_specs)))
        args = (param_shapes, batch_shapes)
    else:  # decode / long_decode
        param_shapes = jax.eval_shape(
            lambda: factory.init_params(key, cfg, dtype, max_seq=max_seq))
        pspecs = shd.param_pspecs(param_shapes, cfg, ctx)
        cache_shapes = jax.eval_shape(
            lambda: factory.init_cache(cfg, shape.global_batch,
                                       shape.seq_len, dtype))
        cache_specs = shd.cache_pspecs(cache_shapes, cfg, ctx)
        batch_shapes = factory.decode_batch_specs(cfg, shape, dtype)
        batch_specs = shd.batch_pspecs(batch_shapes, ctx)
        df = partial(factory.decode, cfg=cfg, ctx=ctx)
        lspec = shd.logits_pspec(cfg, ctx, shape.global_batch)
        fn = jax.jit(df,
                     in_shardings=(named(pspecs), named(cache_specs),
                                   named(batch_specs)),
                     out_shardings=(named(lspec), named(cache_specs)),
                     donate_argnums=(1,))
        args = (param_shapes, cache_shapes, batch_shapes)
    meta = {"skipped": False, "n_chips": n_chips,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind}
    return fn, args, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    fn, args, meta = build_lowerable(arch, shape_name, multi_pod=multi_pod)
    rec.update(meta)
    if meta.get("skipped"):
        if verbose:
            print(f"[dryrun] SKIP {arch} × {shape_name}: {meta['reason']}")
        return rec
    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    costs = hlo_costs.analyze(txt)   # loop-aware FLOPs/bytes/collectives
    n_chips = meta["n_chips"]
    model_flops = cfg.model_flops(shape)
    terms = hlo.roofline_terms(
        costs.flops, costs.bytes, costs.total_coll_bytes, n_chips,
        model_flops)
    rec.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "output_bytes_per_dev": int(ma.output_size_in_bytes),
        "peak_bytes_per_dev": int(ma.peak_memory_in_bytes),
        "hlo_flops_per_dev": costs.flops,
        "hlo_bytes_per_dev": costs.bytes,
        "collective_bytes_per_dev": costs.total_coll_bytes,
        "collectives": {k: {"bytes": costs.coll_bytes[k],
                            "count": costs.coll_count[k]}
                        for k in costs.coll_bytes},
        "bytes_by_op": {k: round(v) for k, v in sorted(
            costs.bytes_by_op.items(), key=lambda kv: -kv[1])},
        "xla_flops_raw": float(ca.get("flops", 0.0)),
        "xla_bytes_raw": float(ca.get("bytes accessed", 0.0)),
        **terms,
    })
    if verbose:
        print(f"[dryrun] OK {arch} × {shape_name} × {rec['mesh']}  "
              f"compile={rec['compile_s']}s  "
              f"peak/dev={rec['peak_bytes_per_dev']/2**30:.2f}GiB  "
              f"terms(c/m/x)=({terms['compute_term_s']:.3e},"
              f"{terms['memory_term_s']:.3e},"
              f"{terms['collective_term_s']:.3e})s  "
              f"dom={terms['dominant']}  "
              f"roofline={terms['roofline_fraction']:.3f}")
    return rec


def save_record(rec: dict, out_dir: str = OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh'].replace('x','_')}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        from repro.configs import list_archs
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
            save_record(rec, args.out)
            gc.collect()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES")
        for f in failures:
            print("  ", f["arch"], f["shape"], f["mesh"], f["error"][:200])
        raise SystemExit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
