"""Perf-iteration probe: per-op-metadata attribution of FLOPs / bytes /
collectives for one dry-run cell — the 'profiler' of the hypothesis loop
(§Perf).  Usage:

  python -m repro.launch.perf_probe --arch qwen2-72b --shape train_4k
"""
import os
if "XLA_FLAGS" not in os.environ or "host_platform" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import collections
import re

from repro.launch import hlo_costs as H


def _tag(line: str, coarse: tuple = ()) -> str:
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return "?"
    p = m.group(1)
    for key in coarse:
        if key in p:
            return key
    segs = [s for s in p.split("/") if s and not s.startswith("jit")]
    return "/".join(segs[-2:])[:70]


def attribute(txt: str, coarse: tuple = ()) -> dict:
    comps = H._split_computations(txt)
    entry = H._entry_name(txt)
    mult = collections.defaultdict(float)

    def walk(name, m, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        for line in comps[name][1:]:
            d = H._DEF_RE.match(line)
            if not d:
                continue
            op = d.group(3)
            if op == "while":
                trip = 1
                tm = H._TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                for key in ("body", "condition"):
                    cm = re.search(key + r"=%?([\w\.\-]+)", line)
                    if cm:
                        walk(cm.group(1), m * trip, depth + 1)
            elif op in ("fusion", "call", "conditional"):
                cm = re.search(r"(?:calls|branch_computations)=\{?%?"
                               r"([\w\.\-]+)", line)
                if cm:
                    walk(cm.group(1), m, depth + 1)

    walk(entry, 1.0)
    flops = collections.Counter()
    bytes_ = collections.Counter()
    colls = collections.Counter()
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        sym = dict(H._PARAM_RE.findall(lines[0]))
        for line in lines[1:]:
            d = H._DEF_RE.match(line)
            if d:
                sym[d.group(1)] = d.group(2)
        for line in lines[1:]:
            d = H._DEF_RE.match(line)
            if not d:
                continue
            _, rtype, op = d.groups()
            base = op[:-6] if op.endswith("-start") else op
            tag = None
            if op == "dot":
                dims = H._shape_dims(rtype)
                nres = 1
                for x in dims:
                    nres *= x
                cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                a = re.search(r"\(([^)]*)\)", line[line.index("dot("):])
                contr = 1
                if cd and a:
                    lhs = a.group(1).split(",")[0].strip().lstrip("%")
                    ld = H._shape_dims(sym.get(lhs, ""))
                    for ci in cd.group(1).split(","):
                        if ci and int(ci) < len(ld):
                            contr *= ld[int(ci)]
                tag = _tag(line, coarse)
                flops[tag] += 2.0 * nres * contr * m
            if base in H._FULL_OPS:
                b = H._type_bytes(rtype)
                ar = re.search(r"\(([^)]*)\)", line[line.index(op + "("):]) \
                    if (op + "(") in line else None
                if ar:
                    for x in ar.group(1).split(","):
                        x = x.strip().lstrip("%")
                        if x in sym:
                            b += H._type_bytes(sym[x])
                bytes_[(base, _tag(line, coarse))] += b * m
            elif base in H._SLICE_OPS:
                bytes_[(base, _tag(line, coarse))] += \
                    H._type_bytes(rtype) * m
            elif base in H._RESULT2_OPS:
                bytes_[(base, _tag(line, coarse))] += \
                    2 * H._type_bytes(rtype) * m
            elif base in H._UPDATE_OPS:
                ar = re.search(r"\(([^)]*)\)", line[line.index(op + "("):]) \
                    if (op + "(") in line else None
                idx = H._UPDATE_OPS[base]
                b = None
                if ar:
                    ops_ = [x.strip().lstrip("%")
                            for x in ar.group(1).split(",")]
                    if len(ops_) > idx and ops_[idx] in sym:
                        b = 2 * H._type_bytes(sym[ops_[idx]])
                bytes_[(base, _tag(line, coarse))] += \
                    (b if b is not None else 2 * H._type_bytes(rtype)) * m
            if base in H._COLLECTIVES:
                colls[(base, _tag(line, coarse))] += \
                    H._type_bytes(rtype) * m
    return {"flops": flops, "bytes": bytes_, "colls": colls}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lowerable
    fn, fargs, meta = build_lowerable(args.arch, args.shape,
                                      multi_pod=args.multi_pod)
    txt = fn.lower(*fargs).compile().as_text()
    att = attribute(txt)
    tf = sum(att["flops"].values())
    print(f"== per-device dot FLOPs: {tf:.3e}  "
          f"(compute term {tf/197e12:.2f}s)")
    for t, f in att["flops"].most_common(args.top):
        print(f"  {f:.3e} {f/max(tf,1)*100:5.1f}%  {t}")
    tb = sum(att["bytes"].values())
    print(f"== per-device HBM bytes: {tb:.3e}  (memory term {tb/819e9:.2f}s)")
    for (op, t), b in att["bytes"].most_common(args.top):
        print(f"  {b:.3e} {b/max(tb,1)*100:5.1f}%  [{op}] {t}")
    tc = sum(att["colls"].values())
    print(f"== per-device collective bytes: {tc:.3e}  "
          f"(collective term ~{tc/50e9:.2f}s)")
    for (op, t), b in att["colls"].most_common(args.top):
        print(f"  {b:.3e} {b/max(tc,1)*100:5.1f}%  [{op}] {t}")


if __name__ == "__main__":
    main()
