"""Shared launcher CLI surface — one home for the RunPlan flags.

launch/dse.py and launch/zoo.py used to carry duplicated argparse blocks
(--mesh/--telemetry/--telemetry-every/--profile/--no-manifest/
--sample-*) that had already drifted once; with the PR-8 packing knobs
(--bucket-by/--max-buckets/--layout/--cache-dir/--no-early-exit) joining
them, the duplication would have doubled.  ``add_plan_args`` installs
the shared flags on a parser and ``plan_from_args`` turns the parsed
namespace into the typed ``RunPlan`` (core/plan.py) that
``sweep``/``grid_sweep``/``simulate`` accept — so a launcher adds ONE
call at each end and every execution knob flows through the same
validated object.

``add_sample_args`` covers the per-class timing-table sweep triples
(--sample-lat/--sample-disp), shared by both launchers but not part of
the RunPlan (they shape the CONFIG GRID, not the execution).
"""
from __future__ import annotations

import argparse
import contextlib

from repro.core.plan import BUCKET_POLICIES, LAYOUTS, RunPlan


def add_plan_args(ap: argparse.ArgumentParser) -> None:
    """Install the shared execution/packing/observability flags.  Read
    them back with ``plan_from_args``."""
    # -- execution / distribution ------------------------------------------
    ap.add_argument("--mesh", nargs=2, type=int, metavar=("A", "B"),
                    help="distribute over a 2-D ('cfg','sm') device mesh — "
                         "A cfg-devices × B sm-devices (needs A*B devices; "
                         "on CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count before jax initializes)")
    ap.add_argument("--max-cycles", type=int, default=1 << 15,
                    help="per-kernel quantum-loop horizon (timeout guard)")
    ap.add_argument("--no-early-exit", action="store_true",
                    help="disable the entry-convergence early exit "
                         "(core/engine.py) — debugging knob; results are "
                         "bit-identical either way")
    # -- bucketed lane packing ---------------------------------------------
    ap.add_argument("--bucket-by", choices=BUCKET_POLICIES, default="none",
                    help="group grid workload lanes into buckets of "
                         "similar padded shape / predicted cost and "
                         "compile one program per bucket "
                         "(core/batch.py:bucket_workloads)")
    ap.add_argument("--max-buckets", type=int, default=None,
                    help="bucket count ceiling for --bucket-by; unset with "
                         "--bucket-by cost picks the count that minimizes "
                         "the predicted total padded cost "
                         "(core/batch.py:choose_bucket_count), unset "
                         "otherwise keeps the classic ceiling of 4")
    ap.add_argument("--layout", choices=LAYOUTS, default="padded",
                    help="kernel-trace layout: 'ragged' concatenates "
                         "kernels with an instr_base offset table instead "
                         "of NOP-padding to the longest kernel")
    # -- compile caching ----------------------------------------------------
    ap.add_argument("--cache-dir", default="", metavar="DIR",
                    help="persistent XLA compilation cache directory — "
                         "compiled programs survive the process "
                         "(core/plan.py:enable_persistent_cache)")
    ap.add_argument("--no-aot-cache", action="store_true",
                    help="disable the in-process AOT executable cache "
                         "(core/sweep.py:timed_call)")
    # -- observability ------------------------------------------------------
    ap.add_argument("--telemetry", type=int, default=0, metavar="S",
                    help="sample the per-SM counter timeline into S "
                         "preallocated rows per lane (core/telemetry.py); "
                         "0 = off (compiled program unchanged)")
    ap.add_argument("--telemetry-every", type=int, default=1, metavar="N",
                    help="sampling cadence in quanta (default 1)")
    ap.add_argument("--profile", default="", metavar="DIR",
                    help="capture a jax.profiler (XLA-level) trace of the "
                         "run into DIR, alongside the manifest")
    ap.add_argument("--no-manifest", action="store_true",
                    help="skip writing the run manifest JSON under "
                         "experiments/runs/")


def add_sample_args(ap: argparse.ArgumentParser, when: str) -> None:
    """The per-class timing-table sweep triples (repeatable), shared by
    both launchers; ``when`` names the flag they depend on in help."""
    ap.add_argument("--sample-lat", nargs=3, action="append", default=[],
                    metavar=("CLASS", "LO", "HI"),
                    help=f"with {when}: config lanes step the per-class "
                         "result latency of CLASS "
                         "(fp32/int32/sfu/tensor/ldg/stg/bar) from LO to "
                         "HI; repeatable")
    ap.add_argument("--sample-disp", nargs=3, action="append", default=[],
                    metavar=("CLASS", "LO", "HI"),
                    help=f"with {when}: config lanes step the per-class "
                         "dispatch interval of CLASS from LO to HI; "
                         "repeatable")
    ap.add_argument("--sample-seed", type=int, default=None, metavar="SEED",
                    help="draw the --sample-* lanes uniformly at random "
                         "from [LO, HI] with this seed instead of the "
                         "deterministic LO..HI linear steps (PCG64; same "
                         "seed, same lanes)")


def add_search_args(ap: argparse.ArgumentParser) -> None:
    """The analytic-prune search knobs (core/search.py), dse-only."""
    ap.add_argument("--search", action="store_true",
                    help="search the config space instead of sweeping a "
                         "fixed grid: propose candidates, score them ALL "
                         "with the analytical surrogate (core/analytic.py),"
                         " cycle-accurately verify only the predicted "
                         "top-k per round (core/search.py)")
    ap.add_argument("--search-rounds", type=int, default=3,
                    help="propose→score→verify rounds (default 3)")
    ap.add_argument("--search-topk", type=int, default=8,
                    help="candidates verified per round in ONE sweep() "
                         "call (default 8)")
    ap.add_argument("--search-seed", type=int, default=0,
                    help="proposer seed — the full candidate sequence and "
                         "top-k are bit-reproducible per seed")
    ap.add_argument("--search-cands", type=int, default=256,
                    help="candidates proposed and analytically scored per "
                         "round (default 256)")
    ap.add_argument("--search-spread", type=float, default=2.0,
                    help="search box half-width: each base config entry "
                         "spans [v/spread, v*spread] (default 2.0); "
                         "--sample-* triples override per-class table "
                         "bounds")


def plan_from_args(args: argparse.Namespace) -> RunPlan:
    """The parsed shared flags as a validated RunPlan.  Builds the mesh
    here (--mesh A B), so launchers never touch jax devices directly."""
    mesh = None
    if getattr(args, "mesh", None):
        from repro.core.distribute import make_mesh
        mesh = make_mesh(*args.mesh)
    return RunPlan(
        mesh=mesh,
        max_cycles=args.max_cycles,
        early_exit=not args.no_early_exit,
        bucket_by=args.bucket_by,
        max_buckets=args.max_buckets,
        layout=args.layout,
        cache_dir=args.cache_dir or None,
        aot_cache=not args.no_aot_cache,
        telemetry_samples=args.telemetry,
        telemetry_every=args.telemetry_every,
        # search knobs exist only on parsers that called add_search_args
        search_seed=getattr(args, "search_seed", 0),
        search_rounds=getattr(args, "search_rounds", 3),
        search_topk=getattr(args, "search_topk", 8),
    )


def add_service_args(ap: argparse.ArgumentParser) -> None:
    """The sim-server knobs (launch/serve.py → core/service.py): base
    hardware config and the batch-former's flush rule."""
    ap.add_argument("--base", choices=("tiny", "3080ti"), default="tiny",
                    help="base GPU config the server compiles for; job "
                         "overrides may only touch dynamic knobs "
                         "(sim/config.py:DYNAMIC_FIELDS + scheduler + "
                         "per-class tables)")
    ap.add_argument("--batch-lanes", type=int, default=8,
                    help="flush the queue once this many lanes are "
                         "waiting (the batch-size half of the flush rule)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="flush when the oldest pending job has waited "
                         "this long (the deadline half of the flush rule)")
    ap.add_argument("--lane-quantum", type=int, default=None, metavar="Q",
                    help="round each bucket's lane count up to a multiple "
                         "of Q by repeating live lanes — padded slots "
                         "carry real requests and AOT signatures stay "
                         "stable as batch sizes drift")
    ap.add_argument("--manifests", action="store_true",
                    help="write a per-job run manifest (queue/compile/"
                         "execute latency split) under experiments/runs/")


def base_config(name: str):
    from repro.sim.config import RTX3080TI, TINY
    return {"tiny": TINY, "3080ti": RTX3080TI}[name]


def service_from_args(args: argparse.Namespace, plan=None):
    """A configured (threaded) SimService from the parsed service+plan
    flags."""
    from repro.core.service import SimService
    return SimService(
        base=base_config(args.base),
        plan=plan,
        batch_lanes=args.batch_lanes,
        max_wait_s=args.max_wait_ms / 1000.0,
        lane_quantum=args.lane_quantum,
        manifests=args.manifests,
    )


def profile_ctx(args):
    """jax.profiler trace capture context for --profile DIR (nullcontext
    when off)."""
    if not getattr(args, "profile", ""):
        return contextlib.nullcontext()
    import jax
    return jax.profiler.trace(args.profile)
