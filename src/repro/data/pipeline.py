"""Deterministic sharded synthetic token pipeline.

Batches are a pure function of (seed, step) — after a restart the pipeline
replays exactly, which is what makes checkpoint/resume bit-reproducible
(fault-tolerance test).  A background prefetch thread keeps `depth` batches
ready; construction is host-side numpy (cheap) with device_put on demand.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import factory, whisper


@dataclass
class DataConfig:
    seed: int = 1234
    prefetch_depth: int = 2


def make_batch_np(cfg: ArchConfig, shape: ShapeSpec, seed: int,
                  step: int) -> dict:
    """Pure (seed, step) -> batch."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.enc_dec:
        out["frames"] = rng.standard_normal(
            (b, whisper.ENC_LEN, cfg.d_model), dtype=np.float32)
        tok = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
        out["tokens"], out["labels"] = tok[:, :-1], tok[:, 1:]
    elif cfg.frontend == "vision":
        out["embeds"] = rng.standard_normal(
            (b, s, cfg.d_model), dtype=np.float32)
        out["labels"] = rng.integers(0, cfg.vocab_size, (b, s),
                                     dtype=np.int32)
    else:
        tok = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
        out["tokens"], out["labels"] = tok[:, :-1], tok[:, 1:]
    return out


class Pipeline:
    """Prefetching iterator starting at `start_step` (for resume)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec,
                 data_cfg: DataConfig = DataConfig(),
                 start_step: int = 0, shardings=None):
        self.cfg, self.shape, self.dc = cfg, shape, data_cfg
        self.step = start_step
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=data_cfg.prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = make_batch_np(self.cfg, self.shape, self.dc.seed, step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        while True:
            step, batch = self._q.get()
            if step == self.step:      # drop stale prefetches after resume
                break
        self.step += 1
        if self.shardings is not None:
            batch = jax.device_put(batch, self.shardings)
        return batch

    def close(self):
        self._stop.set()
