"""GPU timing-model configuration (Accel-sim's role, TPU-native rewrite).

Default parameters model the paper's NVIDIA RTX 3080 Ti (Table 1):
80 SMs × 48 warps, 4 sub-cores/SM, 128 KB L1/SM, 6 MB L2 over 24 memory
partitions (48 slices), 24 DRAM channels.

Timing abstraction (documented deviations from Accel-sim in DESIGN.md):
  · warp-level issue model (GTO/LRR) with per-sub-core unit dispatch ports
  · L1 per SM (set-assoc, LRU), L2 slices + DRAM channels with queueing
    modeled by exact max-plus recurrences (deterministic)
  · the machine operates on a ``quantum`` of Δ=16 cycles: the memory system
    processes its event horizon once per quantum and CTA dispatch happens at
    quantum boundaries.  Δ ≤ every SM↔memory latency, so SM shards can run a
    full quantum locally — this is what makes the parallelization exact
    (DESIGN.md §2, "communication window").
"""
from __future__ import annotations

from dataclasses import dataclass, fields

import jax
import jax.numpy as jnp
from jax.tree_util import register_dataclass

# instruction classes (BAR = CTA-level barrier, __syncthreads)
FP32, INT32, SFU, TENSOR, LDG, STG, BAR = range(7)
N_CLASSES = 7
CLASS_NAMES = ("fp32", "int32", "sfu", "tensor", "ldg", "stg", "bar")
# execution units (per sub-core dispatch ports)
U_FP32, U_INT, U_SFU, U_TENSOR, U_LSU = range(5)
N_UNITS = 5

# class → execution unit is STRUCTURAL (which port an op occupies), not a
# timing numeric — it stays a static table baked into the program.
UNIT_OF_CLASS = (U_FP32, U_INT, U_SFU, U_TENSOR, U_LSU, U_LSU, U_INT)
# default result latency per class (LDG latency is cache-dependent and
# comes from cache.l1_hit_lat / the memory system, so its entry is inert)
LATENCY_OF_CLASS = (4, 4, 16, 8, 0, 0, 1)
# default dispatch interval (cycles the port stays busy per issue)
DISPATCH_OF_CLASS = (1, 1, 4, 2, 1, 1, 1)

# warp scheduler selector (a *dynamic* config value — traced, vmappable)
SCHED_GTO, SCHED_LRR = 0, 1
SCHEDULERS = {"gto": SCHED_GTO, "lrr": SCHED_LRR}

# scalar timing parameters that are plain numerics inside the compiled
# program: they may differ lane-by-lane in a batched design-space sweep.
DYNAMIC_FIELDS = ("l1_hit_lat", "l2_lat", "part_lat", "dram_burst",
                  "dram_row_penalty", "icnt_lat")
# table-valued dynamic leaves, (N_CLASSES,) each
TABLE_FIELDS = ("lat", "disp")
# every flat key split_config understands (the wire format of overrides)
DYN_KEYS = DYNAMIC_FIELDS + ("sched",) + TABLE_FIELDS


def class_index(name: str) -> int:
    """Instruction-class index by name ('fp32', 'sfu', ...)."""
    try:
        return CLASS_NAMES.index(name.lower())
    except ValueError:
        raise ValueError(
            f"unknown instruction class {name!r}; expected one of "
            f"{CLASS_NAMES}") from None


# ---------------------------------------------------------------------------
# DynConfig — the typed dynamic half of a GPU config
# ---------------------------------------------------------------------------

@register_dataclass
@dataclass(frozen=True)
class CoreDyn:
    """SM-core timing: per-class tables + the scheduler selector.

    ``lat[c]`` — result latency of instruction class ``c`` (N_CLASSES,);
    the LDG entry is inert (load latency is cache-dependent: l1_hit_lat on
    a hit, memory-system response on a miss).  ``disp[c]`` — dispatch
    interval: cycles the issue port stays busy per issue.  ``sched`` —
    SCHED_GTO / SCHED_LRR, branchless inside the program."""
    lat: jax.Array
    disp: jax.Array
    sched: jax.Array


@register_dataclass
@dataclass(frozen=True)
class CacheDyn:
    l1_hit_lat: jax.Array
    l2_lat: jax.Array


@register_dataclass
@dataclass(frozen=True)
class MemDyn:
    part_lat: jax.Array
    dram_burst: jax.Array
    dram_row_penalty: jax.Array


@register_dataclass
@dataclass(frozen=True)
class IcntDyn:
    icnt_lat: jax.Array


# flat key → (group attr, leaf attr): the mapping between the legacy flat
# override dict and the typed tree
_FLAT_TO_GROUP = {
    "lat": ("core", "lat"), "disp": ("core", "disp"),
    "sched": ("core", "sched"),
    "l1_hit_lat": ("cache", "l1_hit_lat"), "l2_lat": ("cache", "l2_lat"),
    "part_lat": ("mem", "part_lat"), "dram_burst": ("mem", "dram_burst"),
    "dram_row_penalty": ("mem", "dram_row_penalty"),
    "icnt_lat": ("icnt", "icnt_lat"),
}


@register_dataclass
@dataclass(frozen=True)
class DynConfig:
    """Typed, registered pytree of every traced timing parameter.

    Grouped by machine layer: ``core`` (per-class latency/dispatch tables
    + scheduler selector), ``cache`` (L1/L2 latencies), ``mem`` (partition
    + DRAM timing), ``icnt`` (interconnect latency).  Every leaf is an
    int32 array inside the compiled program, so a lane-stacked batch of
    DynConfigs (core/sweep.py:stack_dyn) vmaps/shards the whole engine
    over configs — including the (N_CLASSES,) tables, which ride along as
    (n_lanes, N_CLASSES) leaves."""
    core: CoreDyn
    cache: CacheDyn
    mem: MemDyn
    icnt: IcntDyn

    @classmethod
    def from_flat(cls, src: dict) -> "DynConfig":
        """Build from a flat {key: value} dict (DYN_KEYS complete)."""
        groups = {"core": {}, "cache": {}, "mem": {}, "icnt": {}}
        for k, v in src.items():
            g, leaf = _FLAT_TO_GROUP[k]
            groups[g][leaf] = jnp.asarray(v, jnp.int32)
        return cls(core=CoreDyn(**groups["core"]),
                   cache=CacheDyn(**groups["cache"]),
                   mem=MemDyn(**groups["mem"]),
                   icnt=IcntDyn(**groups["icnt"]))

    def flat(self) -> dict:
        """The inverse of ``from_flat`` — flat {key: array} view."""
        return {k: getattr(getattr(self, g), leaf)
                for k, (g, leaf) in _FLAT_TO_GROUP.items()}


def _concrete_int(x):
    """Python int of a concrete scalar, or None when traced/abstract."""
    try:
        return int(x)
    except (TypeError, jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return None


def check_dyn(static: "StaticConfig", dyn: DynConfig, lane: str = "") -> None:
    """Python-level (pre-trace) validation of one dynamic lane against its
    StaticConfig: table shapes are (N_CLASSES,) and the machine invariant
    quantum Δ ≤ icnt_lat holds (SM shards run one full quantum between
    memory exchanges — a lane violating it would let a response land
    inside the current window and silently diverge from sequential
    semantics).  Concrete values only; traced leaves are skipped."""
    where = f"{lane}: " if lane else ""
    for name in TABLE_FIELDS:
        tbl = getattr(dyn.core, name)
        if tuple(tbl.shape) != (N_CLASSES,):
            raise ValueError(
                f"{where}dyn table '{name}' must have shape ({N_CLASSES},) "
                f"(one entry per instruction class {CLASS_NAMES}), got "
                f"{tuple(tbl.shape)}")
    icnt = _concrete_int(dyn.icnt.icnt_lat)
    if icnt is not None and static.quantum > icnt:
        raise ValueError(
            f"{where}quantum Δ={static.quantum} must be ≤ icnt_lat={icnt} "
            "(SM shards run one full quantum between memory exchanges; "
            "this lane would break the exactness window)")


@dataclass(frozen=True)
class StaticConfig:
    """Shape-determining (hashable, jit-static) half of a GPU config.

    Two configs with equal ``StaticConfig`` produce identical state/trace
    array shapes, so a whole batch of them can run under one ``vmap`` —
    only the dynamic pytree (``split_config``) varies per lane.
    """
    n_sm: int
    warps_per_sm: int
    n_subcores: int
    max_cta_per_sm: int
    l1_sets: int
    l1_ways: int
    l2_slices: int
    l2_sets: int
    l2_ways: int
    dram_channels: int
    dram_row_div: int
    quantum: int
    mshr_per_sm: int
    addrset_cap: int
    mem_blocks: int
    # in-trace counter-timeline telemetry (core/telemetry.py).  0 samples
    # (the default) keeps the state pytree and the compiled program
    # bit-for-bit identical to a telemetry-free build; > 0 preallocates a
    # (telemetry_samples, N_COUNTERS) buffer sampled every
    # ``telemetry_every``-th quantum.  Shape-determining, hence static.
    telemetry_samples: int = 0
    telemetry_every: int = 1


def static_part(cfg) -> StaticConfig:
    """Extract the hashable static half from a full GPUConfig (identity on
    an already-static config)."""
    if isinstance(cfg, StaticConfig):
        return cfg
    return StaticConfig(
        **{f.name: getattr(cfg, f.name) for f in fields(StaticConfig)})


def _check_override_keys(src: dict, need_all: bool) -> None:
    """ValueError naming unknown (always) and missing (when the dict must
    be self-contained, i.e. no GPUConfig to fall back on) override keys.
    A self-contained dict must supply EVERY dynamic key, the per-class
    ``lat``/``disp`` tables included — the legacy default-table shim is
    gone (build a ``DynConfig`` or pass the tables explicitly)."""
    unknown = sorted(set(src) - set(DYN_KEYS))
    if unknown:
        raise ValueError(
            f"unknown dynamic override key(s) {unknown}; valid keys are "
            f"{sorted(DYN_KEYS)}")
    if need_all:
        missing = sorted(set(DYN_KEYS) - set(src))
        if missing:
            raise ValueError(
                f"missing dynamic override key(s) {missing}: a StaticConfig "
                "carries no timing values, so the override dict must supply "
                f"every dynamic key {sorted(DYN_KEYS)} — including the "
                f"per-class tables {TABLE_FIELDS} (LATENCY_OF_CLASS / "
                "DISPATCH_OF_CLASS are the defaults to start from, or pass "
                "a typed DynConfig)")


def split_config(cfg: "GPUConfig | StaticConfig", dyn_overrides=None):
    """(GPUConfig) -> (StaticConfig, DynConfig).

    The dynamic half is a typed, registered pytree (``DynConfig``) whose
    leaves — scalar latencies, the scheduler selector, and the per-class
    ``lat``/``disp`` tables — are all traced int32 values inside the
    compiled simulator, so a stacked batch of them (one lane per candidate
    config) vmaps the whole engine over configs.

    ``dyn_overrides`` may be a ``DynConfig`` (used as-is) or a flat dict
    keyed by ``DYN_KEYS``.  Unknown/missing keys raise ``ValueError`` by
    name; table overrides are length-checked against ``N_CLASSES`` here,
    at split time.  A self-contained dict (StaticConfig route) must
    supply the ``lat``/``disp`` tables too — the legacy default-table
    shim was removed after its one-release deprecation window.
    """
    if isinstance(cfg, StaticConfig):
        if dyn_overrides is None:
            raise ValueError("StaticConfig alone has no dynamic values")
        static = cfg
        if isinstance(dyn_overrides, DynConfig):
            check_dyn(static, dyn_overrides)
            return static, dyn_overrides
        src = dict(dyn_overrides)
        _check_override_keys(src, need_all=True)
    else:
        static = static_part(cfg)
        if isinstance(dyn_overrides, DynConfig):
            check_dyn(static, dyn_overrides)
            return static, dyn_overrides
        src = {k: getattr(cfg, k) for k in DYNAMIC_FIELDS}
        src["sched"] = SCHEDULERS[cfg.scheduler]
        src["lat"] = cfg.lat_of_class
        src["disp"] = cfg.disp_of_class
        if dyn_overrides:
            overrides = dict(dyn_overrides)
            _check_override_keys(overrides, need_all=False)
            src.update(overrides)
    for name in TABLE_FIELDS:
        shape = tuple(jnp.shape(src[name]))
        if shape != (N_CLASSES,):
            raise ValueError(
                f"dynamic table '{name}' must have {N_CLASSES} entries "
                f"(one per instruction class {CLASS_NAMES}), got shape "
                f"{shape}")
    dyn = DynConfig.from_flat(src)
    check_dyn(static, dyn)
    return static, dyn


@dataclass(frozen=True)
class GPUConfig:
    # table 1
    n_sm: int = 80
    warps_per_sm: int = 48
    n_subcores: int = 4
    max_cta_per_sm: int = 16
    # L1: 128 KB / 128 B lines = 1024 lines
    l1_sets: int = 128
    l1_ways: int = 8
    l1_hit_lat: int = 32
    # L2: 6 MB / 48 slices / 128 B = 1024 lines per slice
    l2_slices: int = 48
    l2_sets: int = 128
    l2_ways: int = 8
    l2_lat: int = 32
    # memory partitions / DRAM
    dram_channels: int = 24
    part_lat: int = 8
    dram_burst: int = 4
    dram_row_penalty: int = 24
    dram_row_div: int = 64       # blocks per DRAM row
    # interconnect
    icnt_lat: int = 16
    # machine quantum (Δ): must be ≤ icnt_lat
    quantum: int = 16
    # misc
    mshr_per_sm: int = 32
    addrset_cap: int = 2048      # per-SM unique-address stat set
    scheduler: str = "gto"       # gto | lrr
    mem_blocks: int = 1 << 22    # simulated VRAM in 128 B blocks
    # counter-timeline telemetry (core/telemetry.py): number of snapshot
    # rows to preallocate (0 = off, the default — program unchanged) and
    # the sampling cadence in quanta
    telemetry_samples: int = 0
    telemetry_every: int = 1
    # per-class timing tables (dynamic: sweepable lane-by-lane).  The LDG
    # latency entry is inert — load latency is cache-dependent.
    lat_of_class: tuple = LATENCY_OF_CLASS
    disp_of_class: tuple = DISPATCH_OF_CLASS

    def __post_init__(self):
        assert self.quantum <= self.icnt_lat, (
            f"quantum Δ={self.quantum} must be ≤ icnt_lat={self.icnt_lat} "
            "(SM shards run one full quantum between memory exchanges)")
        assert self.warps_per_sm % self.n_subcores == 0, (
            f"warps_per_sm={self.warps_per_sm} must be divisible by "
            f"n_subcores={self.n_subcores}")
        assert self.telemetry_samples >= 0, self.telemetry_samples
        assert self.telemetry_every >= 1, (
            f"telemetry_every={self.telemetry_every} must be ≥ 1 "
            "(sampling cadence in quanta)")
        for name in ("lat_of_class", "disp_of_class"):
            tbl = getattr(self, name)
            if not isinstance(tbl, tuple):       # keep the config hashable
                object.__setattr__(self, name, tuple(int(v) for v in tbl))
                tbl = getattr(self, name)
            if len(tbl) != N_CLASSES:
                raise ValueError(
                    f"{name} must have {N_CLASSES} entries (one per "
                    f"instruction class {CLASS_NAMES}), got {len(tbl)}")


RTX3080TI = GPUConfig()

# a small config for fast tests
TINY = GPUConfig(n_sm=8, warps_per_sm=8, n_subcores=2, l1_sets=16, l1_ways=4,
                 l2_slices=4, l2_sets=16, l2_ways=4, dram_channels=2,
                 mshr_per_sm=8, addrset_cap=256)
