"""GPU timing-model configuration (Accel-sim's role, TPU-native rewrite).

Default parameters model the paper's NVIDIA RTX 3080 Ti (Table 1):
80 SMs × 48 warps, 4 sub-cores/SM, 128 KB L1/SM, 6 MB L2 over 24 memory
partitions (48 slices), 24 DRAM channels.

Timing abstraction (documented deviations from Accel-sim in DESIGN.md):
  · warp-level issue model (GTO/LRR) with per-sub-core unit dispatch ports
  · L1 per SM (set-assoc, LRU), L2 slices + DRAM channels with queueing
    modeled by exact max-plus recurrences (deterministic)
  · the machine operates on a ``quantum`` of Δ=16 cycles: the memory system
    processes its event horizon once per quantum and CTA dispatch happens at
    quantum boundaries.  Δ ≤ every SM↔memory latency, so SM shards can run a
    full quantum locally — this is what makes the parallelization exact
    (DESIGN.md §2, "communication window").
"""
from __future__ import annotations

from dataclasses import dataclass, fields

import jax.numpy as jnp

# instruction classes (BAR = CTA-level barrier, __syncthreads)
FP32, INT32, SFU, TENSOR, LDG, STG, BAR = range(7)
N_CLASSES = 7
# execution units (per sub-core dispatch ports)
U_FP32, U_INT, U_SFU, U_TENSOR, U_LSU = range(5)
N_UNITS = 5

UNIT_OF_CLASS = (U_FP32, U_INT, U_SFU, U_TENSOR, U_LSU, U_LSU, U_INT)
# result latency per class (LDG latency is cache-dependent)
LATENCY_OF_CLASS = (4, 4, 16, 8, 0, 0, 1)
# dispatch interval (cycles the port stays busy per issue)
DISPATCH_OF_CLASS = (1, 1, 4, 2, 1, 1, 1)

# warp scheduler selector (a *dynamic* config value — traced, vmappable)
SCHED_GTO, SCHED_LRR = 0, 1
SCHEDULERS = {"gto": SCHED_GTO, "lrr": SCHED_LRR}

# timing parameters that are plain numerics inside the compiled program:
# they may differ lane-by-lane in a batched design-space sweep.
DYNAMIC_FIELDS = ("l1_hit_lat", "l2_lat", "part_lat", "dram_burst",
                  "dram_row_penalty", "icnt_lat")


@dataclass(frozen=True)
class StaticConfig:
    """Shape-determining (hashable, jit-static) half of a GPU config.

    Two configs with equal ``StaticConfig`` produce identical state/trace
    array shapes, so a whole batch of them can run under one ``vmap`` —
    only the dynamic pytree (``split_config``) varies per lane.
    """
    n_sm: int
    warps_per_sm: int
    n_subcores: int
    max_cta_per_sm: int
    l1_sets: int
    l1_ways: int
    l2_slices: int
    l2_sets: int
    l2_ways: int
    dram_channels: int
    dram_row_div: int
    quantum: int
    mshr_per_sm: int
    addrset_cap: int
    mem_blocks: int


def static_part(cfg) -> StaticConfig:
    """Extract the hashable static half from a full GPUConfig (identity on
    an already-static config)."""
    if isinstance(cfg, StaticConfig):
        return cfg
    return StaticConfig(
        **{f.name: getattr(cfg, f.name) for f in fields(StaticConfig)})


def split_config(cfg: "GPUConfig | StaticConfig", dyn_overrides=None):
    """(GPUConfig) -> (StaticConfig, dynamic pytree).

    The dynamic pytree is a flat dict of int32 scalars — every leaf is a
    traced value inside the compiled simulator, so a stacked batch of them
    (one lane per candidate config) vmaps the whole engine over configs.
    ``sched`` carries the scheduler selector (SCHED_GTO / SCHED_LRR).
    """
    if isinstance(cfg, StaticConfig):
        if dyn_overrides is None:
            raise ValueError("StaticConfig alone has no dynamic values")
        static = cfg
        src = dict(dyn_overrides)
    else:
        static = static_part(cfg)
        src = {k: getattr(cfg, k) for k in DYNAMIC_FIELDS}
        src["sched"] = SCHEDULERS[cfg.scheduler]
        if dyn_overrides:
            src.update(dyn_overrides)
    dyn = {k: jnp.asarray(v, jnp.int32) for k, v in src.items()}
    return static, dyn


@dataclass(frozen=True)
class GPUConfig:
    # table 1
    n_sm: int = 80
    warps_per_sm: int = 48
    n_subcores: int = 4
    max_cta_per_sm: int = 16
    # L1: 128 KB / 128 B lines = 1024 lines
    l1_sets: int = 128
    l1_ways: int = 8
    l1_hit_lat: int = 32
    # L2: 6 MB / 48 slices / 128 B = 1024 lines per slice
    l2_slices: int = 48
    l2_sets: int = 128
    l2_ways: int = 8
    l2_lat: int = 32
    # memory partitions / DRAM
    dram_channels: int = 24
    part_lat: int = 8
    dram_burst: int = 4
    dram_row_penalty: int = 24
    dram_row_div: int = 64       # blocks per DRAM row
    # interconnect
    icnt_lat: int = 16
    # machine quantum (Δ): must be ≤ icnt_lat
    quantum: int = 16
    # misc
    mshr_per_sm: int = 32
    addrset_cap: int = 2048      # per-SM unique-address stat set
    scheduler: str = "gto"       # gto | lrr
    mem_blocks: int = 1 << 22    # simulated VRAM in 128 B blocks

    def __post_init__(self):
        assert self.quantum <= self.icnt_lat, (
            f"quantum Δ={self.quantum} must be ≤ icnt_lat={self.icnt_lat} "
            "(SM shards run one full quantum between memory exchanges)")
        assert self.warps_per_sm % self.n_subcores == 0, (
            f"warps_per_sm={self.warps_per_sm} must be divisible by "
            f"n_subcores={self.n_subcores}")


RTX3080TI = GPUConfig()

# a small config for fast tests
TINY = GPUConfig(n_sm=8, warps_per_sm=8, n_subcores=2, l1_sets=16, l1_ways=4,
                 l2_slices=4, l2_sets=16, l2_ways=4, dram_channels=2,
                 mshr_per_sm=8, addrset_cap=256)
