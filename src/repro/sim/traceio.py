"""Accel-sim SASS trace ingestion: real-app traces → ``KernelTrace`` IR.

The simulator's first *real-workload* path.  Accel-sim's tracer (NVBit)
emits one text file per kernel launch; this module parses a documented
**subset** of that format and lowers each kernel onto the existing
procedural IR (sim/trace.py), so trace-derived workloads flow unchanged
through the batched frontend — core/batch.py padding, grid_sweep, the
2-D ('cfg','sm') mesh and ``--sample-lat`` table sweeps.

SUBSET GRAMMAR (line oriented; blank lines ignored)::

    trace      := kernel+
    kernel     := header+ tb*
    header     := "-" key "=" value
                  # required: "kernel name", "grid dim = (x,y,z)",
                  #           "block dim = (x,y,z)"
                  # recognized: "kernel id", "shmem"
                  # any other "-key = value" line is tolerated and
                  # recorded (dropped), e.g. nregs / binary version /
                  # shmem base_addr / nvbit version
    tb         := "#BEGIN_TB" tbhead warpblk+ "#END_TB"
    tbhead     := "thread block = x,y,z"
    warpblk    := "warp = N" ["insts = N"] insn+
    insn       := PC MASK NDEST REG*NDEST OPCODE NSRC REG*NSRC
                  MEMWIDTH [addrinfo]
    addrinfo   := MODE BASEADDR rest*      # required iff MEMWIDTH > 0
                  # MODE 0: full per-thread address list (BASEADDR is
                  #         the first); MODE 1: base + stride;
                  #         MODE 2: base + per-thread deltas.
                  # Only the warp's BASE address is consumed — the IR
                  # addresses at warp granularity.  Other modes raise
                  # TraceFormatError.

WHAT IS KEPT / DROPPED

* The IR replays ONE instruction list on every warp of the grid, so the
  canonical stream is **thread block 0, lowest warp id**.  Warps whose
  (post-drop) opcode sequence differs are counted in
  ``KernelFit.divergent_warps`` and excluded from address fitting.
* ``EXIT`` / ``RET`` are dropped (the IR has no control flow; a stream
  simply ends).  Branches (BRA/…) issue like INT32 ALU ops.
* Opcodes classify into the ``N_CLASSES`` instruction classes by their
  first dotted token (``classify_opcode``): FP32/INT32/SFU/TENSOR/
  LDG/STG/BAR.  Shared-memory ops (LDS/STS/LDSM) have no class of their
  own — they lower to INT32 (issue-slot cost only, no DRAM traffic) and
  are counted in ``KernelFit.shmem_ops``.  Unknown opcodes lower to
  INT32 and are counted in ``KernelFit.unknown_ops``.
* ``dep[i]`` is True iff instruction *i* reads a general register that
  instruction *i-1* wrote (R255/RZ excluded) — the IR models only
  prev-instruction dependencies.  ``dep[0]`` is always False.
* CTA/warp shape: ``n_ctas = gx*gy*gz``; ``warps_per_cta =
  ceil(bx*by*bz / 32)``.  ``max_warps_per_cta=`` splits oversized CTAs
  into ``ceil(wpc/max)`` CTAs of at most ``max`` warps (approximation:
  the barrier scope shrinks with the CTA).

ADDRESS-FIT SEMANTICS

Real address streams are fitted, per memory instruction, to the IR's
procedural generators (sim/trace.py:gen_address), working on 128-byte
block addresses modulo ``mem_blocks`` (default 1<<22, matching the
built-in configs).  Observations are the per-warp base addresses of the
conforming warps, keyed by ``gwarp = tb_linear*warps_per_cta + warp``
and the instruction's position in the *lowered* stream (not its SASS
PC).  Three candidates are scored by mean circular distance (blocks):

    A_STREAM :  (p*4096 + gwarp*8   + pc%8 ) % mem_blocks
    A_STRIDED:  (p*4096 + gwarp*257 + pc*31) % mem_blocks
    A_RANDOM :  hash(gwarp, pc, p)           (brute-forced p < 4096)

The lowest-error candidate wins (ties: STREAM, then STRIDED — with a
single observed gwarp the linear fits are inherently ambiguous; give
the fitter ≥2 gwarps to disambiguate).  The per-instruction error and
kernel aggregates are recorded in ``KernelFit`` — a *fit-error stat*,
so a lossy ingest is visible, never silent.  A stream synthesized from
the generators themselves round-trips exactly within each mode's
recoverable param window: the linear modes only ever observe
``p*4096 mod mem_blocks``, so STREAM/STRIDED params recover modulo
``mem_blocks/4096`` (1024 at the default ``mem_blocks``; a larger
param generates the *identical* address stream), while A_RANDOM params
recover exactly for p < 4096 (tests/test_traceio.py).

API:  ``parse_trace_text`` / ``parse_trace_file`` → ``ParsedKernel``s;
``lower_kernel`` → (``KernelTrace``, ``KernelFit``); ``load_trace(path)``
→ ``TraceIngest`` (whole-file Workload + per-kernel fit stats);
``synthesize_trace`` is the inverse (IR → subset text) used by the
round-trip conformance tests.  CLI: ``python -m repro.launch.trace_ingest
{inspect,summarize,convert} PATH`` and ``python -m repro.launch.zoo
--trace FILE|DIR``.
"""
from __future__ import annotations

import math
import os
import re
from collections import Counter
from dataclasses import dataclass, field, replace

import numpy as np

from repro.sim.config import (BAR, CLASS_NAMES, FP32, INT32, LDG, SFU, STG,
                              TENSOR)
from repro.sim.trace import (A_RANDOM, A_STREAM, A_STRIDED, KernelTrace,
                             Workload)

DEFAULT_MEM_BLOCKS = 1 << 22     # matches GPUConfig.mem_blocks (TINY + 3080Ti)
BLOCK_BYTES = 128                # one simulated memory block
_RANDOM_PARAM_SPACE = 4096       # brute-force window for A_RANDOM recovery

# first dotted opcode token → instruction class
_FP32_OPS = {"FADD", "FMUL", "FFMA", "FSET", "FSETP", "FSEL", "FMNMX",
             "FCHK", "FRND", "F2F", "DADD", "DMUL", "DFMA", "HADD2",
             "HMUL2", "HFMA2"}
_SFU_OPS = {"MUFU", "RCP", "LG2", "EX2", "RSQ", "SQRT"}
_TENSOR_OPS = {"HMMA", "IMMA", "BMMA", "DMMA"}
_LOAD_OPS = {"LDG", "LD", "LDL"}
_STORE_OPS = {"STG", "ST", "STL", "ATOM", "ATOMG", "RED"}
_BAR_OPS = {"BAR", "MEMBAR"}
_SHMEM_OPS = {"LDS", "STS", "LDSM"}
_DROP_OPS = {"EXIT", "RET"}
# known ALU/control opcodes (classification falls through to INT32 for
# anything unlisted, but unknowns are *counted* — see KernelFit)
_INT_OPS = {"IMAD", "IADD", "IADD3", "ISETP", "IABS", "IMNMX", "LOP",
            "LOP3", "PLOP3", "LEA", "SHF", "SHL", "SHR", "MOV", "MOV32I",
            "SEL", "S2R", "CS2R", "PRMT", "POPC", "FLO", "BREV", "VOTE",
            "VOTEU", "NOP", "BRA", "BRX", "BSSY", "BSYNC", "I2F", "F2I",
            "I2I", "ISCADD", "LDC", "ULDC", "UMOV", "UIMAD", "USHF",
            "ULOP3", "R2P", "P2R"}

_REG_RE = re.compile(r"^(U?R|U?P)\d+$")
_DIM_RE = re.compile(r"^\((\d+),(\d+),(\d+)\)$")


class TraceFormatError(ValueError):
    """Malformed trace input; names the offending line number."""

    def __init__(self, msg: str, line_no: int | None = None,
                 path: str = ""):
        self.line_no = line_no
        self.path = path
        where = path or "<trace>"
        if line_no is not None:
            where += f":{line_no}"
        super().__init__(f"{where}: {msg}")


def classify_opcode(opcode: str) -> int | None:
    """Instruction class of a SASS opcode (first dotted token), or None
    for dropped control ops (EXIT/RET)."""
    head = opcode.split(".")[0].upper()
    if head in _DROP_OPS:
        return None
    if head in _FP32_OPS:
        return FP32
    if head in _SFU_OPS:
        return SFU
    if head in _TENSOR_OPS:
        return TENSOR
    if head in _LOAD_OPS:
        return LDG
    if head in _STORE_OPS:
        return STG
    if head in _BAR_OPS:
        return BAR
    return INT32


def _opcode_kind(opcode: str) -> str:
    """'known' | 'shmem' | 'unknown' — bookkeeping for KernelFit."""
    head = opcode.split(".")[0].upper()
    if head in _SHMEM_OPS:
        return "shmem"
    known = (_FP32_OPS | _SFU_OPS | _TENSOR_OPS | _LOAD_OPS | _STORE_OPS
             | _BAR_OPS | _DROP_OPS | _INT_OPS)
    return "known" if head in known else "unknown"


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

@dataclass
class ParsedInstr:
    pc: int
    mask: int
    dests: tuple
    opcode: str
    srcs: tuple
    mem_width: int
    base_addr: int | None = None      # byte address; None for non-mem
    line_no: int = 0


@dataclass
class ParsedWarp:
    warp_id: int
    instrs: list = field(default_factory=list)
    declared_insts: int | None = None


@dataclass
class ParsedTB:
    block: tuple
    warps: list = field(default_factory=list)


@dataclass
class ParsedKernel:
    name: str
    grid: tuple
    block: tuple
    kernel_id: int = 0
    shmem: int = 0
    extras: dict = field(default_factory=dict)   # tolerated-and-dropped headers
    tbs: list = field(default_factory=list)

    @property
    def n_ctas(self) -> int:
        return self.grid[0] * self.grid[1] * self.grid[2]

    @property
    def threads_per_cta(self) -> int:
        return self.block[0] * self.block[1] * self.block[2]

    @property
    def warps_per_cta(self) -> int:
        return max(1, math.ceil(self.threads_per_cta / 32))

    def tb_linear(self, block: tuple) -> int:
        gx, gy, _gz = self.grid
        x, y, z = block
        return x + gx * (y + gy * z)


def _parse_dim(value: str, no: int, path: str, min_val: int = 1) -> tuple:
    m = _DIM_RE.match(value.replace(" ", ""))
    if not m:
        raise TraceFormatError(
            f"expected dimension tuple '(x,y,z)', got {value!r}", no, path)
    dims = tuple(int(g) for g in m.groups())
    if any(d < min_val for d in dims):
        raise TraceFormatError(
            f"dimension must be >= {min_val}: {value!r}", no, path)
    return dims


def _parse_int(tok: str, what: str, no: int, path: str, base: int = 10) -> int:
    try:
        return int(tok, base)
    except ValueError:
        raise TraceFormatError(
            f"expected {what}, got {tok!r}", no, path) from None


def _parse_regs(toks: list, i: int, count: int, no: int,
                path: str) -> tuple:
    if i + count > len(toks):
        raise TraceFormatError(
            f"instruction line truncated: expected {count} register(s), "
            f"found {len(toks) - i}", no, path)
    regs = toks[i:i + count]
    for r in regs:
        if not _REG_RE.match(r):
            raise TraceFormatError(
                f"expected register operand, got {r!r}", no, path)
    return tuple(regs)


def _parse_instr(toks: list, no: int, path: str) -> ParsedInstr:
    if len(toks) < 5:
        raise TraceFormatError(
            "instruction line truncated: need at least "
            "'PC MASK NDEST OPCODE NSRC'", no, path)
    pc = _parse_int(toks[0], "hex PC", no, path, base=16)
    mask = _parse_int(toks[1], "hex active mask", no, path, base=16)
    ndest = _parse_int(toks[2], "dest-register count", no, path)
    i = 3
    dests = _parse_regs(toks, i, ndest, no, path)
    i += ndest
    if i >= len(toks):
        raise TraceFormatError("instruction line truncated: missing opcode",
                               no, path)
    opcode = toks[i]
    i += 1
    if i >= len(toks):
        raise TraceFormatError(
            f"instruction line truncated after opcode {opcode!r}", no, path)
    nsrc = _parse_int(toks[i], "source-register count", no, path)
    i += 1
    srcs = _parse_regs(toks, i, nsrc, no, path)
    i += nsrc
    if i >= len(toks):
        raise TraceFormatError(
            f"instruction line truncated: missing mem_width for {opcode!r}",
            no, path)
    mem_width = _parse_int(toks[i], "mem_width", no, path)
    i += 1
    base_addr = None
    if mem_width > 0:
        if i + 1 >= len(toks):
            raise TraceFormatError(
                f"mem op {opcode!r} (width {mem_width}) is missing its "
                "address info: expected 'MODE BASEADDR ...'", no, path)
        mode = _parse_int(toks[i], "address compression mode", no, path)
        if mode not in (0, 1, 2):
            raise TraceFormatError(
                f"unsupported address compression mode {mode} (the subset "
                "accepts 0=list, 1=base+stride, 2=base+deltas)", no, path)
        base_addr = _parse_int(toks[i + 1], "base address", no, path, base=0)
        # trailing tokens (stride / deltas / the rest of an address list)
        # are part of addrinfo and dropped: the IR addresses per warp.
    elif i < len(toks):
        raise TraceFormatError(
            f"unexpected trailing tokens {toks[i:]} on a non-memory "
            "instruction (mem_width = 0)", no, path)
    return ParsedInstr(pc=pc, mask=mask, dests=dests, opcode=opcode,
                       srcs=srcs, mem_width=mem_width, base_addr=base_addr,
                       line_no=no)


def parse_trace_text(text: str, path: str = "<trace>") -> list:
    """Parse subset trace text into a list of ``ParsedKernel``."""
    kernels: list = []
    kern: ParsedKernel | None = None
    hdr: dict = {}
    extras: dict = {}
    tb: ParsedTB | None = None
    warp: ParsedWarp | None = None

    def close_warp(no):
        nonlocal warp
        if warp is None:
            return
        if (warp.declared_insts is not None
                and warp.declared_insts != len(warp.instrs)):
            raise TraceFormatError(
                f"warp {warp.warp_id} declared insts = "
                f"{warp.declared_insts} but has {len(warp.instrs)} "
                "instruction lines", no, path)
        warp = None

    def materialize(no):
        """Promote accumulated header lines into a ParsedKernel."""
        nonlocal kern, hdr, extras
        if kern is not None:
            return
        missing = [k for k in ("kernel name", "grid dim", "block dim")
                   if k not in hdr]
        if missing:
            raise TraceFormatError(
                f"kernel header incomplete: missing "
                f"{['-' + m for m in missing]}", no, path)
        kern = ParsedKernel(
            name=hdr["kernel name"], grid=hdr["grid dim"],
            block=hdr["block dim"], kernel_id=int(hdr.get("kernel id", 0)),
            shmem=int(hdr.get("shmem", 0)), extras=dict(extras))
        hdr, extras = {}, {}

    def flush_kernel(no):
        nonlocal kern
        if kern is None and (hdr or extras):
            materialize(no)
        if kern is not None:
            kernels.append(kern)
            kern = None

    for no, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line:
            continue

        if line.startswith("-"):
            if tb is not None:
                raise TraceFormatError(
                    "header line inside a #BEGIN_TB block", no, path)
            if "=" not in line:
                raise TraceFormatError(
                    f"malformed header line {line!r}: expected "
                    "'-key = value'", no, path)
            key, _, value = line[1:].partition("=")
            key, value = key.strip(), value.strip()
            if key == "kernel name":
                flush_kernel(no)            # a new kernel begins
                hdr = {"kernel name": value}
                extras = {}
            elif key in ("grid dim", "block dim"):
                hdr[key] = _parse_dim(value, no, path)
            elif key in ("kernel id", "shmem"):
                hdr[key] = _parse_int(value, f"integer for '-{key}'", no,
                                      path)
            else:
                extras[key] = value         # tolerated, dropped
            continue

        if line == "#BEGIN_TB":
            materialize(no)
            if tb is not None:
                raise TraceFormatError("#BEGIN_TB inside an open TB block",
                                       no, path)
            tb = ParsedTB(block=())
            continue

        if line == "#END_TB":
            if tb is None:
                raise TraceFormatError("#END_TB without #BEGIN_TB", no, path)
            close_warp(no)
            if not tb.block:
                raise TraceFormatError(
                    "TB block missing its 'thread block = x,y,z' line",
                    no, path)
            if len(kern.tbs) >= kern.n_ctas:
                raise TraceFormatError(
                    f"more thread blocks than grid size {kern.n_ctas}",
                    no, path)
            kern.tbs.append(tb)
            tb = None
            continue

        if line.startswith("thread block"):
            if tb is None:
                raise TraceFormatError(
                    "'thread block' line outside #BEGIN_TB", no, path)
            _, _, value = line.partition("=")
            tb.block = _parse_dim(f"({value.strip()})", no, path, min_val=0)
            if any(c >= g for c, g in zip(tb.block, kern.grid)):
                raise TraceFormatError(
                    f"thread block {tb.block} outside grid {kern.grid}",
                    no, path)
            continue

        if line.startswith("warp"):
            if tb is None:
                raise TraceFormatError("'warp = N' line outside #BEGIN_TB",
                                       no, path)
            close_warp(no)
            _, _, value = line.partition("=")
            wid = _parse_int(value.strip(), "warp id", no, path)
            warp = ParsedWarp(warp_id=wid)
            tb.warps.append(warp)
            continue

        if line.startswith("insts"):
            if warp is None:
                raise TraceFormatError(
                    "'insts = N' line outside a warp block", no, path)
            _, _, value = line.partition("=")
            warp.declared_insts = _parse_int(value.strip(),
                                             "instruction count", no, path)
            continue

        # anything else must be an instruction line inside a warp block
        if tb is None or warp is None:
            raise TraceFormatError(
                f"unexpected line {line!r}: instruction lines must appear "
                "inside a '#BEGIN_TB' / 'warp = N' block", no, path)
        warp.instrs.append(_parse_instr(line.split(), no, path))

    if tb is not None:
        raise TraceFormatError("unterminated #BEGIN_TB block (missing "
                               "#END_TB)", len(text.splitlines()), path)
    flush_kernel(len(text.splitlines()))
    if not kernels:
        raise TraceFormatError("no kernels found", None, path)
    return kernels


def parse_trace_file(path: str) -> list:
    with open(path) as f:
        text = f.read()
    return parse_trace_text(text, path=path)


# ---------------------------------------------------------------------------
# address fitting
# ---------------------------------------------------------------------------

def _circ_err(pred: np.ndarray, obs: np.ndarray, mem_blocks: int):
    d = np.abs(pred.astype(np.int64) - obs.astype(np.int64))
    return np.minimum(d, mem_blocks - d)


def _fit_linear(gwarps, addrs, pc, mem_blocks, coeff, pc_term):
    off = (coeff * gwarps.astype(np.int64) + pc_term) % mem_blocks
    cand = (np.rint(((addrs.astype(np.int64) - off) % mem_blocks) / 4096)
            .astype(np.int64) % max(mem_blocks // 4096, 1))
    vals, counts = np.unique(cand, return_counts=True)
    p = int(vals[np.argmax(counts)])
    pred = (p * 4096 + off) % mem_blocks
    return p, float(_circ_err(pred, addrs, mem_blocks).mean())


def _fit_random(gwarps, addrs, pc, mem_blocks):
    ps = np.arange(min(_RANDOM_PARAM_SPACE, mem_blocks), dtype=np.int64)
    h = (gwarps.astype(np.int64)[None, :] * 2654435761
         + pc * 40503 + ps[:, None] * 97) % (1 << 32)
    pred = h % mem_blocks
    errs = _circ_err(pred, addrs[None, :].astype(np.int64),
                     mem_blocks).mean(axis=1)
    best = int(np.argmin(errs))
    return int(ps[best]), float(errs[best])


def fit_addresses(gwarps: np.ndarray, addrs: np.ndarray, pc: int,
                  mem_blocks: int = DEFAULT_MEM_BLOCKS):
    """Fit observed per-gwarp block addresses of one instruction to the
    procedural generators.  Returns (mode, param, mean_err_blocks).
    Candidates are scored by mean circular distance; the lowest error
    wins, ties resolving STREAM → STRIDED → RANDOM."""
    gwarps = np.asarray(gwarps, np.int64)
    addrs = np.asarray(addrs, np.int64) % mem_blocks
    p_st, e_st = _fit_linear(gwarps, addrs, pc, mem_blocks, 8, pc % 8)
    p_sd, e_sd = _fit_linear(gwarps, addrs, pc, mem_blocks, 257, 31 * pc)
    p_rn, e_rn = _fit_random(gwarps, addrs, pc, mem_blocks)
    best = min(((e_st, 0, A_STREAM, p_st), (e_sd, 1, A_STRIDED, p_sd),
                (e_rn, 2, A_RANDOM, p_rn)))
    return best[2], best[3], best[0]


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

@dataclass
class KernelFit:
    """Ingest/conformance stats recorded while lowering one kernel."""
    name: str
    n_instr: int = 0
    n_mem: int = 0                       # fitted memory instructions
    n_warps_seen: int = 0                # warp streams observed in the trace
    divergent_warps: int = 0             # opcode stream != canonical
    dropped: dict = field(default_factory=dict)    # opcode head -> count
    shmem_ops: int = 0                   # LDS/STS/... lowered to INT32
    unknown_ops: int = 0                 # unlisted opcodes lowered to INT32
    fit_err: list = field(default_factory=list)    # per-mem-instr, blocks
    cta_split: int = 1                   # ctas each original CTA became

    @property
    def fit_err_mean(self) -> float:
        return float(np.mean(self.fit_err)) if self.fit_err else 0.0

    @property
    def fit_err_max(self) -> float:
        return float(np.max(self.fit_err)) if self.fit_err else 0.0

    def summary(self) -> dict:
        return {
            "name": self.name, "n_instr": self.n_instr, "n_mem": self.n_mem,
            "n_warps_seen": self.n_warps_seen,
            "divergent_warps": self.divergent_warps,
            "dropped": dict(self.dropped), "shmem_ops": self.shmem_ops,
            "unknown_ops": self.unknown_ops,
            "fit_err_mean": round(self.fit_err_mean, 4),
            "fit_err_max": round(self.fit_err_max, 4),
            "cta_split": self.cta_split,
        }


_ZERO_REGS = {"R255", "UR255"}           # RZ reads as zero: never a dep


def _dep_chain(instrs: list) -> np.ndarray:
    dep = np.zeros(len(instrs), bool)
    for i in range(1, len(instrs)):
        prev_dests = {d for d in instrs[i - 1].dests
                      if d not in _ZERO_REGS}
        srcs = {s for s in instrs[i].srcs if s not in _ZERO_REGS}
        dep[i] = bool(prev_dests & srcs)
    return dep


def lower_kernel(pk: ParsedKernel, mem_blocks: int = DEFAULT_MEM_BLOCKS,
                 max_warps_per_cta: int | None = None):
    """Lower one parsed kernel to the IR.  Returns (KernelTrace, KernelFit).

    Canonical stream: thread block 0 (grid-linear order), lowest warp id,
    control ops dropped.  Other conforming warps contribute only their
    memory base addresses, which are fitted per instruction to the
    A_STREAM / A_STRIDED / A_RANDOM generators (module docstring)."""
    fit = KernelFit(name=pk.name)
    if not pk.tbs:
        raise TraceFormatError(
            f"kernel {pk.name!r} has no thread blocks", None, "")
    tbs = sorted(pk.tbs, key=lambda tb: pk.tb_linear(tb.block))
    wpc = pk.warps_per_cta

    def stream_of(warp: ParsedWarp) -> list:
        kept = []
        for ins in warp.instrs:
            cls = classify_opcode(ins.opcode)
            if cls is None:
                head = ins.opcode.split(".")[0].upper()
                fit.dropped[head] = fit.dropped.get(head, 0) + 1
                continue
            kept.append((cls, ins))
        return kept

    canon_tb = tbs[0]
    if not canon_tb.warps:
        raise TraceFormatError(
            f"kernel {pk.name!r}: thread block {canon_tb.block} has no "
            "warps", None, "")
    canon_warp = min(canon_tb.warps, key=lambda w: w.warp_id)
    canon = stream_of(canon_warp)
    if not canon:
        raise TraceFormatError(
            f"kernel {pk.name!r}: canonical warp has no instructions "
            "after dropping control ops", None, "")

    ops = np.array([c for c, _ in canon], np.int32)
    dep = _dep_chain([ins for _, ins in canon])
    addr_mode = np.zeros(len(canon), np.int32)
    addr_param = np.zeros(len(canon), np.int32)
    fit.n_instr = len(canon)
    for cls, ins in canon:
        kind = _opcode_kind(ins.opcode)
        if kind == "shmem":
            fit.shmem_ops += 1
        elif kind == "unknown":
            fit.unknown_ops += 1

    canon_sig = [(c, ins.opcode) for c, ins in canon]
    # gather per-gwarp base addresses from every conforming warp
    obs: dict = {i: {} for i, (c, _) in enumerate(canon)
                 if c in (LDG, STG)}
    for tb in tbs:
        linear = pk.tb_linear(tb.block)
        for w in tb.warps:
            if w.warp_id >= wpc:
                raise TraceFormatError(
                    f"kernel {pk.name!r}: warp id {w.warp_id} >= "
                    f"warps_per_cta {wpc}", None, "")
            fit.n_warps_seen += 1
            stream = stream_of(w) if w is not canon_warp else canon
            if [(c, ins.opcode) for c, ins in stream] != canon_sig:
                fit.divergent_warps += 1
                continue
            gwarp = linear * wpc + w.warp_id
            for i, (_c, ins) in enumerate(stream):
                if i in obs and ins.base_addr is not None:
                    obs[i][gwarp] = (ins.base_addr // BLOCK_BYTES) \
                        % mem_blocks

    for i in sorted(obs):
        if not obs[i]:
            continue                     # mem op with no observed addresses
        gw = np.array(sorted(obs[i]), np.int64)
        ad = np.array([obs[i][g] for g in sorted(obs[i])], np.int64)
        mode, param, err = fit_addresses(gw, ad, i, mem_blocks)
        addr_mode[i], addr_param[i] = mode, param
        fit.n_mem += 1
        fit.fit_err.append(err)

    n_ctas = pk.n_ctas
    if max_warps_per_cta is not None and wpc > max_warps_per_cta:
        split = math.ceil(wpc / max_warps_per_cta)
        fit.cta_split = split
        n_ctas *= split
        wpc = math.ceil(wpc / split)

    kt = KernelTrace(name=pk.name, n_ctas=n_ctas, warps_per_cta=wpc,
                     ops=ops, dep=dep, addr_mode=addr_mode,
                     addr_param=addr_param)
    return kt, fit


# ---------------------------------------------------------------------------
# whole-file ingest
# ---------------------------------------------------------------------------

@dataclass
class TraceIngest:
    """A lowered trace file: the Workload plus per-kernel fit stats."""
    workload: Workload
    fits: list                           # KernelFit per kernel
    path: str = ""

    def summary(self) -> dict:
        errs = [e for f in self.fits for e in f.fit_err]
        return {
            "name": self.workload.name, "path": self.path,
            "n_kernels": len(self.workload.kernels),
            "total_ctas": self.workload.total_ctas,
            "n_instr": [k.n_instr for k in self.workload.kernels],
            "fit_err_mean": round(float(np.mean(errs)), 4) if errs else 0.0,
            "fit_err_max": round(float(np.max(errs)), 4) if errs else 0.0,
            "kernels": [f.summary() for f in self.fits],
        }


def trace_name(path: str) -> str:
    """Zoo registry name of a trace file: ``trace:<stem>``."""
    stem = os.path.splitext(os.path.basename(path))[0]
    return f"trace:{stem}"


def load_trace(path: str, mem_blocks: int = DEFAULT_MEM_BLOCKS,
               max_warps_per_cta: int | None = None) -> TraceIngest:
    """Parse + lower one trace file into a multi-kernel Workload (kernels
    in file order) named ``trace:<stem>``."""
    parsed = parse_trace_file(path)
    kernels, fits = [], []
    for pk in parsed:
        kt, f = lower_kernel(pk, mem_blocks=mem_blocks,
                             max_warps_per_cta=max_warps_per_cta)
        kernels.append(kt)
        fits.append(f)
    w = Workload(trace_name(path), kernels)
    return TraceIngest(workload=w, fits=fits, path=path)


def trace_files(path: str) -> list:
    """``.trace`` files under a file-or-directory path, sorted by name."""
    if os.path.isdir(path):
        return sorted(
            os.path.join(path, f) for f in os.listdir(path)
            if f.endswith(".trace"))
    return [path]


def load_traces(path: str, **kw) -> list:
    """Ingest a file or every ``*.trace`` in a directory."""
    files = trace_files(path)
    if not files:
        raise FileNotFoundError(f"no .trace files under {path!r}")
    return [load_trace(f, **kw) for f in files]


# ---------------------------------------------------------------------------
# synthesis (IR → subset text) — the round-trip half of the conformance
# suite, and a way to turn any procedural workload into a trace fixture
# ---------------------------------------------------------------------------

_SYNTH_OPCODE = {FP32: "FFMA", INT32: "IMAD", SFU: "MUFU.RCP",
                 TENSOR: "HMMA.1688.F32", LDG: "LDG.E.SYS",
                 STG: "STG.E.SYS", BAR: "BAR.SYNC"}
_SYNTH_BASE = 0x7F0000000000        # ≡ 0 mod (mem_blocks * BLOCK_BYTES)


def _gen_address_np(mode: int, param: int, gwarp: int, pc: int,
                    mem_blocks: int) -> int:
    """Numpy mirror of sim/trace.py:gen_address for one (gwarp, pc)."""
    if mode == A_STREAM:
        return (param * 4096 + gwarp * 8 + pc % 8) % mem_blocks
    if mode == A_STRIDED:
        return (param * 4096 + gwarp * 257 + pc * 31) % mem_blocks
    h = (gwarp * 2654435761 + pc * 40503 + param * 97) % (1 << 32)
    return int(h % mem_blocks)


def synthesize_kernel(kt: KernelTrace, kernel_id: int = 1,
                      mem_blocks: int = DEFAULT_MEM_BLOCKS) -> str:
    """Subset trace text for one KernelTrace: every CTA/warp emitted,
    addresses generated by the procedural generators, so parsing and
    re-lowering recovers the IR exactly within the fitter's param
    windows — STREAM/STRIDED params modulo ``mem_blocks/4096`` (1024 by
    default; larger params alias to the same addresses), A_RANDOM
    params < 4096.  A_NONE memory ops come back as A_RANDOM — the two
    are runtime-identical.  Every synthesized instruction gets a dest
    register so ``dep`` round-trips even across stores and barriers."""
    lines = [
        f"-kernel name = {kt.name}",
        f"-kernel id = {kernel_id}",
        f"-grid dim = ({kt.n_ctas},1,1)",
        f"-block dim = ({kt.warps_per_cta * 32},1,1)",
        "-shmem = 0",
        "-nregs = 32",
        "-binary version = 86",
        "",
    ]
    n = kt.n_instr
    for cta in range(kt.n_ctas):
        lines.append("#BEGIN_TB")
        lines.append("")
        lines.append(f"thread block = {cta},0,0")
        lines.append("")
        for w in range(kt.warps_per_cta):
            gwarp = cta * kt.warps_per_cta + w
            lines.append(f"warp = {w}")
            lines.append(f"insts = {n + 1}")
            for i in range(n):
                dest = f"R{i + 2}"
                src = f"R{i + 1}" if kt.dep[i] else "R1"
                opcode = _SYNTH_OPCODE[int(kt.ops[i])]
                cls = int(kt.ops[i])
                if cls in (LDG, STG):
                    blk = _gen_address_np(
                        int(kt.addr_mode[i]), int(kt.addr_param[i]),
                        gwarp, i, mem_blocks)
                    addr = _SYNTH_BASE + blk * BLOCK_BYTES
                    lines.append(
                        f"{i * 16:04x} ffffffff 1 {dest} {opcode} 1 {src} "
                        f"4 1 0x{addr:x} 4")
                else:
                    lines.append(
                        f"{i * 16:04x} ffffffff 1 {dest} {opcode} 1 {src} 0")
            lines.append(f"{n * 16:04x} ffffffff 0 EXIT 0 0")
            lines.append("")
        lines.append("#END_TB")
        lines.append("")
    return "\n".join(lines)


def synthesize_trace(workload: Workload,
                     mem_blocks: int = DEFAULT_MEM_BLOCKS) -> str:
    """Subset trace text for a whole (multi-kernel) workload."""
    return "\n".join(
        synthesize_kernel(k, kernel_id=i + 1, mem_blocks=mem_blocks)
        for i, k in enumerate(workload.kernels))


def class_histogram(kt: KernelTrace) -> dict:
    """{class name: count} over one kernel's lowered stream."""
    c = Counter(int(o) for o in kt.ops)
    return {CLASS_NAMES[k]: v for k, v in sorted(c.items())}


def scale_trace_workload(w: Workload, scale: float) -> Workload:
    """Scale a trace-derived workload's CTA counts like the zoo
    generators do (scale=1.0 keeps the real grid)."""
    if scale == 1.0:
        return w
    return Workload(w.name, [
        replace(k, n_ctas=max(1, int(round(k.n_ctas * scale))))
        for k in w.kernels])
