"""Per-workload instruction-mix features for the analytical fast path.

The analytical cost model (core/analytic.py) predicts a workload's cycle
count from a candidate ``DynConfig`` WITHOUT running the engine.  Every
model input that depends only on the trace — per-class instruction
counts, dependency-chain structure, address-pattern mix, CTA/wave
geometry — is extracted HERE, once per (workload, StaticConfig), into a
fixed-length float vector.  The model then combines that vector with a
batch of candidate timing parameters in vectorized numpy, so scoring
thousands of configs costs microseconds per config instead of a
cycle-accurate run.

Feature semantics mirror the engine's actual timing rules
(sim/smcore.py / sim/memsys.py):

  · ``issue[c]`` — per-(SM×subcore) issue volume of class ``c``: each
    sub-core issues one instruction per cycle and its port stays busy
    ``disp[c]`` cycles, so Σ issue[c]·disp[c] is the throughput bound.
  · ``chain[c]`` — wave-weighted count of instructions that DEPEND on a
    previous instruction of class ``c``: a dependent instruction stalls
    its warp ``lat[c]`` cycles (the latency-chain bound).
  · ``dep_load[m]`` — wave-weighted count of instructions depending on a
    previous LDG with address mode ``m`` (stream/strided/random): these
    stalls cost l1_hit_lat on a hit or a full memory round trip
    (l2_lat/part_lat/dram_* + 2·icnt_lat) on a miss — the per-mode split
    lets the calibration fit a different effective miss rate per pattern.
  · ``mem_ch[m]`` — memory operations per DRAM channel by mode (the
    bandwidth bound: each request occupies its channel ``dram_burst``).
  · ``waves`` — CTA waves summed over kernels (per-wave ramp overhead);
    ``instr_sm`` — total issues per SM (scheduler-sensitivity scale).
"""
from __future__ import annotations

import numpy as np

from repro.sim.config import LDG, N_CLASSES, STG, StaticConfig, static_part

# address-pattern buckets (sim/trace.py: A_STREAM/A_STRIDED/A_RANDOM);
# A_NONE loads fold into the stream bucket (best-case locality)
N_MODES = 3

# feature-vector layout
F_ISSUE = 0                       # [0, 7): per-class issue volume
F_CHAIN = F_ISSUE + N_CLASSES     # [7, 14): per-class dependency chain
F_DEP_LOAD = F_CHAIN + N_CLASSES  # [14, 17): dep-on-load by addr mode
F_MEM_CH = F_DEP_LOAD + N_MODES   # [17, 20): mem ops/channel by addr mode
F_WAVES = F_MEM_CH + N_MODES      # 20: total CTA waves
F_INSTR_SM = F_WAVES + 1          # 21: total issues per SM
N_FEATURES = F_INSTR_SM + 1

FEATURE_NAMES = tuple(
    [f"issue_{c}" for c in range(N_CLASSES)]
    + [f"chain_{c}" for c in range(N_CLASSES)]
    + ["dep_load_stream", "dep_load_strided", "dep_load_random",
       "mem_ch_stream", "mem_ch_strided", "mem_ch_random",
       "waves", "instr_sm"])


def kernel_geometry(kernel, scfg: StaticConfig) -> tuple:
    """(total_warps, waves) of one kernel on this machine shape: CTAs
    resident per SM are bounded by both the CTA slot limit and the warp
    slots, and the grid drains in ⌈n_ctas / (resident · n_sm)⌉ waves."""
    resident = min(scfg.max_cta_per_sm,
                   max(scfg.warps_per_sm // max(kernel.warps_per_cta, 1), 1))
    waves = -(-kernel.n_ctas // max(resident * scfg.n_sm, 1))
    return kernel.n_ctas * kernel.warps_per_cta, waves


def kernel_features(kernel, scfg: StaticConfig) -> np.ndarray:
    """One kernel's (N_FEATURES,) contribution (float64)."""
    f = np.zeros(N_FEATURES, np.float64)
    total_warps, waves = kernel_geometry(kernel, scfg)
    ops = np.asarray(kernel.ops, np.int64)
    dep = np.asarray(kernel.dep, bool)
    mode = np.asarray(kernel.addr_mode, np.int64)
    ports = float(scfg.n_sm * scfg.n_subcores)

    cnt = np.bincount(ops, minlength=N_CLASSES)[:N_CLASSES]
    f[F_ISSUE:F_ISSUE + N_CLASSES] = cnt * (total_warps / ports)

    # chain[c]: instructions whose PREDECESSOR is class c and that carry a
    # dep flag — the stall charges the predecessor's result latency
    if len(ops) > 1:
        pred_of_dep = ops[:-1][dep[1:]]
        f[F_CHAIN:F_CHAIN + N_CLASSES] = (
            np.bincount(pred_of_dep, minlength=N_CLASSES)[:N_CLASSES]
            * float(waves))
        dep_ld = pred_of_dep == LDG
        ld_modes = np.clip(mode[:-1][dep[1:]][dep_ld] - 1, 0, N_MODES - 1)
        f[F_DEP_LOAD:F_DEP_LOAD + N_MODES] = (
            np.bincount(ld_modes, minlength=N_MODES)[:N_MODES]
            * float(waves))

    is_mem = (ops == LDG) | (ops == STG)
    mem_modes = np.clip(mode[is_mem] - 1, 0, N_MODES - 1)
    f[F_MEM_CH:F_MEM_CH + N_MODES] = (
        np.bincount(mem_modes, minlength=N_MODES)[:N_MODES]
        * (total_warps / float(max(scfg.dram_channels, 1))))

    f[F_WAVES] = float(waves)
    f[F_INSTR_SM] = len(ops) * total_warps / float(max(scfg.n_sm, 1))
    return f


def workload_features(workload, scfg) -> np.ndarray:
    """Sum of the workload's kernel feature vectors — kernels run
    back-to-back, so their cost contributions add."""
    scfg = static_part(scfg)
    f = np.zeros(N_FEATURES, np.float64)
    for k in workload.kernels:
        f += kernel_features(k, scfg)
    return f
