"""Simulator state: struct-of-arrays pytree.

Layout invariant (this IS the paper's parallelization boundary):
  · arrays with a leading ``n_sm`` axis are touched ONLY by the SM phase
    (embarrassingly parallel — vmap / lax.map / shard_map over that axis);
  · ``mem`` / ``ctrl`` and the global stats are touched ONLY by the
    memory/CTA phases (the serial region, computed replicated);
  · per-SM statistics are isolated per SM (paper §3) and reduced once at
    the end of the run (core/stats.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import telemetry
from repro.sim.config import N_UNITS, StaticConfig


def init_state(cfg: StaticConfig) -> dict:
    ns, w, m = cfg.n_sm, cfg.warps_per_sm, cfg.mshr_per_sm
    sc = cfg.n_subcores
    i32 = jnp.int32
    state = {
        "warp": {
            "pc": jnp.zeros((ns, w), i32),
            "active": jnp.zeros((ns, w), jnp.bool_),
            "ready_at": jnp.zeros((ns, w), i32),
            "pending": jnp.zeros((ns, w), i32),
            "wait_mem": jnp.zeros((ns, w), jnp.bool_),
            "wait_bar": jnp.zeros((ns, w), jnp.bool_),  # at a CTA barrier
            "cta": jnp.full((ns, w), -1, i32),
            "wic": jnp.zeros((ns, w), i32),     # warp index within CTA
        },
        "sm": {
            "last_issued": jnp.full((ns, sc), -1, i32),
            "unit_free": jnp.zeros((ns, sc, N_UNITS), i32),
            "l1_tag": jnp.full((ns, cfg.l1_sets, cfg.l1_ways), -1, i32),
            "l1_lru": jnp.zeros((ns, cfg.l1_sets, cfg.l1_ways), i32),
            "addrset": jnp.full((ns, cfg.addrset_cap), -1, i32),
            "addrset_over": jnp.zeros((ns,), i32),
        },
        "req": {
            "stage": jnp.zeros((ns, m), i32),   # 0 free,1 →L2,2 →DRAM,3 done
            "addr": jnp.zeros((ns, m), i32),
            "t": jnp.zeros((ns, m), i32),
            "warp": jnp.zeros((ns, m), i32),
            "is_store": jnp.zeros((ns, m), jnp.bool_),
        },
        "mem": {
            "l2_tag": jnp.full((cfg.l2_slices, cfg.l2_sets, cfg.l2_ways),
                               -1, i32),
            "l2_lru": jnp.zeros((cfg.l2_slices, cfg.l2_sets, cfg.l2_ways),
                                i32),
            "l2_busy": jnp.zeros((cfg.l2_slices,), i32),
            "dram_busy": jnp.zeros((cfg.dram_channels,), i32),
            "dram_row": jnp.full((cfg.dram_channels,), -1, i32),
        },
        "ctrl": {
            "cycle": jnp.zeros((), i32),
            "next_cta": jnp.zeros((), i32),
            "rr": jnp.zeros((), i32),
            "done_cycle": jnp.full((), -1, i32),
            # original SM id at each array position (identity unless the
            # SM axis was relabeled for a device-assignment policy); CTA
            # round-robin follows ORIGINAL ids so results are invariant.
            "sm_ids": jnp.arange(ns, dtype=i32),
        },
        # --- per-SM stats (parallel region; isolated per SM, reduced at the
        #     epilogue — the paper's data-race fix) -------------------------
        "stats_sm": {
            "issued": jnp.zeros((ns,), i32),
            "issued_mem": jnp.zeros((ns,), i32),
            "l1_hit": jnp.zeros((ns,), i32),
            "l1_miss": jnp.zeros((ns,), i32),
            "cycles_issue": jnp.zeros((ns,), i32),   # cycles with ≥1 issue
            "stall": jnp.zeros((ns,), i32),          # active but no issue
            "warp_cycles": jnp.zeros((ns,), i32),
        },
        # --- global stats (serial region; the paper's "option 3") ----------
        "stats": {
            "l2_hit": jnp.zeros((), i32),
            "l2_miss": jnp.zeros((), i32),
            "dram_req": jnp.zeros((), i32),
            "dram_row_hit": jnp.zeros((), i32),
            "ctas_launched": jnp.zeros((), i32),
        },
    }
    # --- opt-in counter-timeline buffer (core/telemetry.py) ------------
    # only materialized when the StaticConfig asks for samples, so the
    # default state pytree (and hence every compiled program and the
    # determinism golden) is unchanged when telemetry is off.
    if telemetry.enabled(cfg):
        state["telem"] = telemetry.init(cfg)
    return state


def reset_for_kernel(state: dict, cfg: StaticConfig) -> dict:
    """Between kernels: clear warps/requests, flush L1 (Accel-sim semantics),
    keep L2/DRAM state and accumulated stats.

    This is a pure traced function of ``state`` (the fresh arrays are
    shape-only constants from ``init_state``) — it runs INSIDE the
    engine's ``lax.scan`` over the stacked kernel axis
    (core/engine.py:run_workload_stacked), so the kernel-to-kernel reset
    is part of the one compiled workload program rather than a host-side
    step between per-kernel dispatches."""
    s = init_state(cfg)
    new = {
        "warp": s["warp"],
        "sm": dict(state["sm"],
                   l1_tag=s["sm"]["l1_tag"], l1_lru=s["sm"]["l1_lru"],
                   last_issued=s["sm"]["last_issued"],
                   unit_free=jnp.zeros_like(state["sm"]["unit_free"])),
        "req": s["req"],
        "mem": dict(state["mem"]),
        "ctrl": dict(state["ctrl"], next_cta=jnp.zeros((), jnp.int32),
                     done_cycle=jnp.full((), -1, jnp.int32)),
        "stats_sm": dict(state["stats_sm"]),
        "stats": dict(state["stats"]),
    }
    # telemetry buffer (when present) persists across kernels like the
    # accumulated stats — the timeline spans the whole workload
    if "telem" in state:
        new["telem"] = dict(state["telem"])
    return new
