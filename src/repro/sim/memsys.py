"""Memory-system phase: interconnect → L2 slices → DRAM channels.

Runs once per machine quantum (Δ cycles) over the *full* request table —
this is Algorithm 1's serial region (lines 8–19).  Under the sharded
execution mode every device computes it replicated from an all-gathered
table, which preserves the sequential semantics bit-exactly.

Queueing at L2 slices and DRAM channels is an exact M/D/1-style recurrence
  finish_i = max(arrival_i, finish_{i-1}) + service_i
evaluated with a *segmented max-plus associative scan* over requests sorted
by (resource, event-time, row-id) — fully deterministic, independent of the
number of devices and of the window size (the recurrence carries
``busy_until`` across quanta).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sim.config import DynConfig, StaticConfig

BIG = jnp.int32(1 << 30)


def _seg_maxplus(seg_start, service, arrival):
    """finish_i = max(arrival_i, finish_{i-1}) + service_i, reset at segment
    starts.  All inputs sorted by segment; seg_start: bool (first of seg)."""
    a = service.astype(jnp.int32)
    b = (arrival + service).astype(jnp.int32)

    def comb(x, y):
        f1, a1, b1 = x
        f2, a2, b2 = y
        a = jnp.where(f2, a2, a1 + a2)
        b = jnp.where(f2, b2, jnp.maximum(b1 + a2, b2))
        return (f1 | f2, a, b)

    _, _, finish = jax.lax.associative_scan(comb, (seg_start, a, b))
    return finish.astype(jnp.int32)


def _lex_sort(primary, secondary, tertiary, valid):
    """argsort by (primary, secondary, tertiary), invalid rows last.
    int32-safe two-pass stable lexsort (no x64 in this environment):
    secondary and tertiary (< 2^12 rows) pack into one key; a second
    stable pass orders by primary.

    ``secondary`` must be SMALL — callers pass the *quantum-relative*
    event time ``t - t0`` (every valid row satisfies t0 ≤ t < t0 + Δ, so
    it lies in [0, Δ)), never the absolute cycle: an absolute time (up to
    2^20+ cycles) times the row count overflows the packed int32 key on
    long runs and silently scrambles the service order
    (tests/test_memsys.py:test_mem_phase_time_shift_invariance)."""
    r = tertiary.shape[0]
    k2 = secondary * r + tertiary
    k2 = jnp.where(valid, k2, BIG)
    o1 = jnp.argsort(k2, stable=True)
    p = jnp.where(valid, primary, BIG)[o1]
    o2 = jnp.argsort(p, stable=True)
    return o1[o2]


def mem_phase(req: dict, mem: dict, stats: dict, t0, cfg: StaticConfig,
              dyn: DynConfig, sm_ids=None):
    """Process the event horizon [t0, t0+Δ). Returns (req, mem, stats).

    cfg is the hashable static shape config; dyn is the typed DynConfig of
    traced timing parameters (dyn.cache.l2_lat, dyn.mem.part_lat /
    dram_burst / dram_row_penalty, dyn.icnt.icnt_lat) so a vmapped config
    sweep varies them per lane.

    sm_ids: (n_sm,) ORIGINAL SM id per array position — canonical tie-break
    order must follow original ids so results are invariant under SM-axis
    relabeling (the 'dynamic' device-assignment policy)."""
    horizon = t0 + cfg.quantum
    ns, m = req["stage"].shape
    r = ns * m
    stage = req["stage"].reshape(r)
    addr = req["addr"].reshape(r)
    t = req["t"].reshape(r)
    if sm_ids is None:
        sm_ids = jnp.arange(ns, dtype=jnp.int32)
    rid = (sm_ids[:, None] * m
           + jnp.arange(m, dtype=jnp.int32)[None, :]).reshape(r)

    # ---------------- stage 1: arrival at L2 slices -------------------------
    sel1 = (stage == 1) & (t < horizon)
    slc = addr % cfg.l2_slices
    order = _lex_sort(slc, t - t0, rid, sel1)
    o_sel = sel1[order]
    o_slc = jnp.where(o_sel, slc[order], cfg.l2_slices)
    o_t = t[order]
    o_addr = addr[order]
    o_rid = order.astype(jnp.int32)

    seg_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), o_slc[1:] != o_slc[:-1]])
    arrival = jnp.maximum(o_t, mem["l2_busy"][jnp.clip(o_slc, 0,
                                                       cfg.l2_slices - 1)])
    service = jnp.ones((r,), jnp.int32)          # 1 request / cycle / slice
    finish = _seg_maxplus(seg_start, service, arrival)
    start = finish - service

    # L2 tag lookup (snapshot at quantum start)
    l2_set = (o_addr // cfg.l2_slices) % cfg.l2_sets
    slc_c = jnp.clip(o_slc, 0, cfg.l2_slices - 1)
    ways = mem["l2_tag"][slc_c, l2_set]          # (r, ways)
    hit = jnp.any(ways == o_addr[:, None], axis=1) & o_sel
    miss = o_sel & ~hit

    resp_t = start + dyn.cache.l2_lat + dyn.icnt.icnt_lat
    dram_t = start + dyn.cache.l2_lat + dyn.mem.part_lat

    new_stage = jnp.where(hit, 3, jnp.where(miss, 2, stage[order]))
    new_t = jnp.where(hit, resp_t, jnp.where(miss, dram_t, o_t))
    # scatter back (order is a permutation — unique indices)
    stage = stage.at[o_rid].set(new_stage)
    t = t.at[o_rid].set(new_t)

    # busy_until per slice: max finish (commutative -> safe scatter-max)
    l2_busy = mem["l2_busy"].at[slc_c].max(jnp.where(o_sel, finish, 0))

    # LRU touch on hits (monotone time -> scatter-max is exact)
    hway = jnp.argmax(ways == o_addr[:, None], axis=1)
    l2_lru = mem["l2_lru"].at[slc_c, l2_set, hway].max(
        jnp.where(hit, t0, -1))
    # insert on miss: victim = LRU way (snapshot); same-(slice,set) conflicts
    # resolved "last in canonical order wins": scatter-max the canonical
    # rank, then only the winning entry writes its tag (unique indices).
    victim = jnp.argmin(l2_lru[slc_c, l2_set], axis=1)
    rank = jnp.arange(r, dtype=jnp.int32)
    rank_grid = jnp.full(mem["l2_tag"].shape, -1, jnp.int32)
    rank_grid = rank_grid.at[slc_c, l2_set, victim].max(
        jnp.where(miss, rank, -1))
    win = miss & (rank_grid[slc_c, l2_set, victim] == rank)
    vway = jnp.where(win, victim, cfg.l2_ways)     # OOB → dropped
    l2_tag = mem["l2_tag"].at[slc_c, l2_set, vway].set(o_addr, mode="drop")
    l2_lru = l2_lru.at[slc_c, l2_set, vway].set(t0, mode="drop")

    stats = dict(stats,
                 l2_hit=stats["l2_hit"] + jnp.sum(hit, dtype=jnp.int32),
                 l2_miss=stats["l2_miss"] + jnp.sum(miss, dtype=jnp.int32))

    # ---------------- stage 2: DRAM channels --------------------------------
    sel2 = (stage == 2) & (t < horizon)
    ch = (addr % cfg.l2_slices) * cfg.dram_channels // cfg.l2_slices
    order2 = _lex_sort(ch, t - t0, rid, sel2)
    o_sel2 = sel2[order2]
    o_ch = jnp.where(o_sel2, ch[order2], cfg.dram_channels)
    o_t2 = t[order2]
    o_row = (addr[order2] // cfg.dram_row_div)
    o_rid2 = order2.astype(jnp.int32)
    ch_c = jnp.clip(o_ch, 0, cfg.dram_channels - 1)

    seg2 = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), o_ch[1:] != o_ch[:-1]])
    prev_row = jnp.concatenate([jnp.full((1,), -2, jnp.int32), o_row[:-1]])
    prev_row = jnp.where(seg2, mem["dram_row"][ch_c], prev_row)
    row_hit = (o_row == prev_row) & o_sel2
    service2 = jnp.where(row_hit, dyn.mem.dram_burst,
                         dyn.mem.dram_burst + dyn.mem.dram_row_penalty)
    arrival2 = jnp.maximum(o_t2, mem["dram_busy"][ch_c])
    finish2 = _seg_maxplus(seg2, service2, arrival2)

    resp2 = finish2 + dyn.mem.part_lat + dyn.icnt.icnt_lat
    stage = stage.at[o_rid2].set(jnp.where(o_sel2, 3, stage[o_rid2]))
    t = t.at[o_rid2].set(jnp.where(o_sel2, resp2, t[o_rid2]))

    dram_busy = mem["dram_busy"].at[ch_c].max(jnp.where(o_sel2, finish2, 0))
    seg_last = jnp.concatenate([o_ch[1:] != o_ch[:-1],
                                jnp.ones((1,), jnp.bool_)])
    last_sel = seg_last & o_sel2
    dram_row = mem["dram_row"].at[jnp.where(last_sel, ch_c,
                                            cfg.dram_channels - 1)].set(
        jnp.where(last_sel, o_row, mem["dram_row"][cfg.dram_channels - 1]))

    stats = dict(stats,
                 dram_req=stats["dram_req"] + jnp.sum(o_sel2,
                                                      dtype=jnp.int32),
                 dram_row_hit=stats["dram_row_hit"]
                 + jnp.sum(row_hit, dtype=jnp.int32))

    req = dict(req, stage=stage.reshape(ns, m), t=t.reshape(ns, m))
    mem = dict(mem, l2_tag=l2_tag, l2_lru=l2_lru, l2_busy=l2_busy,
               dram_busy=dram_busy, dram_row=dram_row)
    return req, mem, stats
