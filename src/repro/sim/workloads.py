"""Workload zoo — a registry of named synthetic workloads for the sweep
frontend.

Where ``repro.workloads.synthetic`` mimics the paper's Table-2 apps, the
zoo is the *sweep-facing* catalogue: ~8 small generators with deliberately
distinct cache/DRAM/compute signatures, built on the ``build_kernel`` body
DSL, meant to be stacked into one batched (workload × config) program
(core/batch.py + core/sweep.py:grid_sweep).

  gemm_tiled        tensor-core GEMM k-loop: strided A/B tiles, MMA pairs
  stencil           5-point streaming stencil sweeps, barrier per step
  streaming_copy    pure LDG→STG stream, DRAM-bandwidth bound
  strided_transpose large-stride load/store, cache-hostile
  random_gather     dependent random-address loads, latency bound
  reduction_tree    8-way reduction: kernel chain, CTA count ÷8 per level
  tensor_heavy      MMA-dominated, near-zero memory traffic
  mixed             multi-kernel pipeline mixing the above phases

Registry API:  ``zoo_names()`` lists them, ``zoo_workload(name, scale=…)``
builds one (``scale`` shrinks CTA counts like the Table-2 generators).
CLI: ``python -m repro.launch.zoo --list | --run NAME | --grid W C``.

REAL-TRACE WORKLOADS ride the same registry under ``trace:<name>``:
``register_trace(path)`` ingests an Accel-sim SASS trace subset file
(sim/traceio.py) and registers its lowered Workload, after which it
flows through every batched path — padding, ``grid_sweep``, the 2-D
('cfg','sm') mesh, ``--sample-lat`` table sweeps — exactly like a
synthetic workload.  ``zoo_workload('trace:x')`` auto-registers from
the trace search path (``REPRO_TRACE_PATH`` dirs, then the repo's
bundled ``tests/data/traces``) when the name is not yet registered.
``resolve_workload(name)`` is the one-stop resolver used by launchers
and benchmarks: plain zoo names, ``zoo:``/``trace:`` prefixes, and
Table-2 synthetic names (repro.workloads) all work.
"""
from __future__ import annotations

import os

from repro.sim.config import BAR, FP32, INT32, LDG, SFU, STG, TENSOR
from repro.sim.trace import (A_RANDOM, A_STREAM, A_STRIDED, Workload,
                             build_kernel)

ZOO: dict = {}
TRACE_INGESTS: dict = {}   # "trace:<name>" -> traceio.TraceIngest


def register(name: str):
    def deco(fn):
        ZOO[name] = fn
        return fn
    return deco


def zoo_names() -> list:
    return sorted(ZOO)


def zoo_workload(name: str, scale: float = 1.0) -> Workload:
    """Build a zoo workload by registry name.  ``trace:<x>`` names not
    yet registered are auto-registered from the trace search path."""
    if name not in ZOO and name.startswith("trace:"):
        _autoregister_trace(name)
    if name not in ZOO:
        raise KeyError(f"unknown zoo workload {name!r}; "
                       f"available: {', '.join(zoo_names())}")
    return ZOO[name](scale)


# ---------------------------------------------------------------------------
# real-trace workloads (sim/traceio.py) — "trace:<name>" registry entries
# ---------------------------------------------------------------------------

def trace_search_dirs() -> list:
    """Where ``trace:<x>`` names resolve from: ``REPRO_TRACE_PATH``
    (os.pathsep-separated), then the repo's bundled fixture directory."""
    dirs = [d for d in os.environ.get("REPRO_TRACE_PATH", "")
            .split(os.pathsep) if d]
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    dirs.append(os.path.join(root, "tests", "data", "traces"))
    return dirs


def register_trace(path: str) -> str:
    """Ingest one trace file and register it as ``trace:<stem>``.
    Returns the registry name.  ``scale`` on the registered builder
    scales CTA counts like the synthetic generators (1.0 = real grid)."""
    from repro.sim import traceio

    ing = traceio.load_trace(path)
    name = ing.workload.name
    TRACE_INGESTS[name] = ing
    ZOO[name] = lambda scale, _w=ing.workload: \
        traceio.scale_trace_workload(_w, scale)
    return name


def register_traces(path: str) -> list:
    """Register a trace file or every ``*.trace`` in a directory."""
    from repro.sim import traceio

    files = traceio.trace_files(path)
    if not files:
        raise FileNotFoundError(f"no .trace files under {path!r}")
    return [register_trace(f) for f in files]


def _autoregister_trace(name: str) -> None:
    stem = name[len("trace:"):]
    for d in trace_search_dirs():
        candidate = os.path.join(d, stem + ".trace")
        if os.path.exists(candidate):
            register_trace(candidate)
            return


def resolve_workload(name: str, scale: float = 1.0) -> Workload:
    """One resolver for every workload namespace: ``trace:<x>`` and
    ``zoo:<x>`` prefixes, bare zoo names, and the Table-2 synthetic
    generators (repro.workloads.make_workload)."""
    if name.startswith("zoo:"):
        return zoo_workload(name[len("zoo:"):], scale)
    if name.startswith("trace:") or name in ZOO:
        return zoo_workload(name, scale)
    from repro.workloads import make_workload
    return make_workload(name, scale=scale)


def _s(n, scale):  # scaled CTA count, at least 1
    return max(1, int(round(n * scale)))


@register("gemm_tiled")
def _gemm_tiled(scale: float) -> Workload:
    """Tiled GEMM: per k-step two strided tile loads feed two MMA ops;
    streamed epilogue store.  Strided reuse across warps → L2 hits."""
    body = []
    for k in range(6):
        body.append((LDG, False, A_STRIDED, k))          # A tile
        body.append((LDG, False, A_STRIDED, 64 + k))     # B tile
        body.append((TENSOR, True, 0, 0))
        body.append((TENSOR, True, 0, 0))
    body.append((STG, False, A_STREAM, 128))
    return Workload("gemm_tiled", [build_kernel(
        "gemm", n_ctas=_s(768, scale), warps_per_cta=4, body=body,
        repeats=2)])


@register("stencil")
def _stencil(scale: float) -> Workload:
    """5-point stencil, 3 time steps: neighbour streams (5 offsets), FP32
    update chain, barrier, streamed store.  Streaming + high L1 locality."""
    w = Workload("stencil")
    for step in range(3):
        body = [(LDG, False, A_STREAM, 8 * step + off) for off in range(5)]
        body += [(FP32, i == 0, 0, 0) for i in range(6)]
        body.append((BAR, False, 0, 0))
        body.append((STG, False, A_STREAM, 8 * step + 6))
        w.kernels.append(build_kernel(
            f"step{step}", n_ctas=_s(640, scale), warps_per_cta=4,
            body=body, repeats=2))
    return w


@register("streaming_copy")
def _streaming_copy(scale: float) -> Workload:
    """memcpy: back-to-back independent stream loads + stores, almost no
    compute — pure DRAM bandwidth, near-perfect row locality."""
    body = []
    for i in range(4):
        body.append((LDG, False, A_STREAM, i))
        body.append((STG, False, A_STREAM, 32 + i))
    return Workload("streaming_copy", [build_kernel(
        "copy", n_ctas=_s(1280, scale), warps_per_cta=4, body=body,
        repeats=3)])


@register("strided_transpose")
def _strided_transpose(scale: float) -> Workload:
    """Transpose-like: streamed loads written back at a large stride —
    cache-hostile stores, DRAM row churn, light INT addressing."""
    body = []
    for i in range(4):
        body.append((LDG, False, A_STREAM, i))
        body.append((INT32, True, 0, 0))
        body.append((STG, False, A_STRIDED, 32 + i))
    return Workload("strided_transpose", [build_kernel(
        "transpose", n_ctas=_s(640, scale), warps_per_cta=4, body=body,
        repeats=2)])


@register("random_gather")
def _random_gather(scale: float) -> Workload:
    """Pointer-chase analogue: dependent random-address loads with integer
    index math between them — MSHR/latency bound, ~0 row locality."""
    body = []
    for i in range(5):
        body.append((LDG, i > 0, A_RANDOM, i))
        body.append((INT32, True, 0, 0))
    body.append((STG, False, A_RANDOM, 9))
    return Workload("random_gather", [build_kernel(
        "gather", n_ctas=_s(512, scale), warps_per_cta=4, body=body,
        repeats=2)])


@register("reduction_tree")
def _reduction_tree(scale: float) -> Workload:
    """8-way reduction tree: each level's CTA count is an eighth of the
    previous (512 → 64 → 8 → 1) — multi-kernel tail-latency shape (late
    kernels starve most SMs)."""
    w = Workload("reduction_tree")
    n = 512
    level = 0
    while n >= 1:
        body = [(LDG, False, A_STREAM, 4 * level),
                (LDG, False, A_STREAM, 4 * level + 1),
                (FP32, True, 0, 0), (FP32, True, 0, 0),
                (BAR, False, 0, 0),
                (STG, False, A_STREAM, 4 * level + 2)]
        w.kernels.append(build_kernel(
            f"level{level}", n_ctas=_s(n, scale) if n > 1 else 1,
            warps_per_cta=2, body=body))
        n //= 8
        level += 1
        if n == 0:
            break
    return w


@register("tensor_heavy")
def _tensor_heavy(scale: float) -> Workload:
    """MMA-dominated: one operand fetch then long dependent MMA chains
    with an SFU epilogue — compute bound, unit-port limited."""
    body = [(LDG, False, A_STRIDED, 0), (LDG, False, A_STRIDED, 64)]
    body += [(TENSOR, True, 0, 0)] * 10
    body.append((SFU, True, 0, 0))
    body.append((STG, False, A_STREAM, 128))
    return Workload("tensor_heavy", [build_kernel(
        "mma", n_ctas=_s(512, scale), warps_per_cta=4, body=body,
        repeats=3)])


@register("mixed")
def _mixed(scale: float) -> Workload:
    """Multi-kernel pipeline: copy-in → GEMM tile → random gather → small
    reduce.  Kernels differ in length, width and CTA count — the padding
    stress case for the batched frontend."""
    w = Workload("mixed")
    w.kernels.append(build_kernel(
        "copy_in", n_ctas=_s(768, scale), warps_per_cta=4,
        body=[(LDG, False, A_STREAM, 0), (STG, False, A_STREAM, 16)],
        repeats=2))
    gemm = []
    for k in range(4):
        gemm += [(LDG, False, A_STRIDED, k), (LDG, False, A_STRIDED, 64 + k),
                 (TENSOR, True, 0, 0), (TENSOR, True, 0, 0)]
    gemm.append((STG, False, A_STREAM, 128))
    w.kernels.append(build_kernel(
        "gemm", n_ctas=_s(384, scale), warps_per_cta=4, body=gemm))
    w.kernels.append(build_kernel(
        "gather", n_ctas=_s(256, scale), warps_per_cta=2,
        body=[(LDG, False, A_RANDOM, 3), (INT32, True, 0, 0),
              (LDG, True, A_RANDOM, 5), (INT32, True, 0, 0)], repeats=2))
    w.kernels.append(build_kernel(
        "reduce", n_ctas=_s(32, scale), warps_per_cta=2,
        body=[(LDG, False, A_STREAM, 7), (FP32, True, 0, 0),
              (BAR, False, 0, 0), (STG, False, A_STREAM, 9)]))
    return w
