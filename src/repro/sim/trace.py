"""Kernel traces: the simulator's workload representation.

A kernel is a grid of CTAs; every warp executes the same instruction list
(trace-driven, like Accel-sim's trace mode) with per-warp addresses generated
procedurally from (cta, warp, pc) — address *patterns* (streaming / strided /
random) are the workload knobs that matter for cache/DRAM behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.sim.config import FP32, INT32, LDG, SFU, STG, TENSOR  # noqa: F401

# address modes
A_NONE, A_STREAM, A_STRIDED, A_RANDOM = range(4)


@dataclass(eq=False)
class KernelTrace:
    name: str
    n_ctas: int
    warps_per_cta: int
    ops: np.ndarray          # (L,) int32 instruction class
    dep: np.ndarray          # (L,) bool — depends on previous instruction
    addr_mode: np.ndarray    # (L,) int32
    addr_param: np.ndarray   # (L,) int32

    @property
    def n_instr(self) -> int:
        return len(self.ops)

    def __eq__(self, other) -> bool:
        """Full IR equality, array fields elementwise — what the trace
        round-trip conformance tests compare (dataclass default eq is
        ambiguous on ndarrays)."""
        if not isinstance(other, KernelTrace):
            return NotImplemented
        return (self.name == other.name
                and self.n_ctas == other.n_ctas
                and self.warps_per_cta == other.warps_per_cta
                and all(np.array_equal(getattr(self, f), getattr(other, f))
                        for f in ("ops", "dep", "addr_mode", "addr_param")))

    def pack(self) -> dict:
        return {
            "ops": jnp.asarray(self.ops, jnp.int32),
            "dep": jnp.asarray(self.dep, jnp.bool_),
            "addr_mode": jnp.asarray(self.addr_mode, jnp.int32),
            "addr_param": jnp.asarray(self.addr_param, jnp.int32),
            "n_ctas": jnp.asarray(self.n_ctas, jnp.int32),
            "warps_per_cta": jnp.asarray(self.warps_per_cta, jnp.int32),
            "n_instr": jnp.asarray(self.n_instr, jnp.int32),
        }


@dataclass
class Workload:
    name: str
    kernels: list = field(default_factory=list)

    @property
    def total_ctas(self) -> int:
        return sum(k.n_ctas for k in self.kernels)

    def ctas_per_kernel(self) -> list[int]:
        return [k.n_ctas for k in self.kernels]


def build_kernel(name: str, *, n_ctas: int, warps_per_cta: int,
                 body: list[tuple], repeats: int = 1,
                 seed: int = 0) -> KernelTrace:
    """body: list of (op_class, dep, addr_mode, addr_param) tuples."""
    ops, dep, am, ap = [], [], [], []
    for _ in range(repeats):
        for (o, d, m, p) in body:
            ops.append(o)
            dep.append(d)
            am.append(m)
            ap.append(p)
    return KernelTrace(
        name=name, n_ctas=n_ctas, warps_per_cta=warps_per_cta,
        ops=np.asarray(ops, np.int32), dep=np.asarray(dep, bool),
        addr_mode=np.asarray(am, np.int32),
        addr_param=np.asarray(ap, np.int32))


def gen_address(mode, param, gwarp, pc, mem_blocks: int):
    """Vectorized procedural address generator (block addresses)."""
    stream = (param * 4096 + gwarp * 8 + (pc % 8)) % mem_blocks
    strided = (param * 4096 + gwarp * 257 + pc * 31) % mem_blocks
    h = (gwarp.astype(jnp.uint32) * jnp.uint32(2654435761)
         + (pc * 40503 + param * 97).astype(jnp.uint32))
    random = (h % jnp.uint32(mem_blocks)).astype(jnp.int32)
    addr = jnp.where(mode == A_STREAM, stream,
                     jnp.where(mode == A_STRIDED, strided, random))
    return addr.astype(jnp.int32)
