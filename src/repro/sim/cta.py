"""CTA (thread-block) dispatch — Algorithm 1's ``issueBlocksToSMs``.

Runs at quantum boundaries in the serial region (replicated under sharding).
Blocks are dealt round-robin over SMs starting from a rotating pointer,
matching the paper's description of Accel-sim's distribution; warp slots are
filled lowest-index-first.  Fully vectorized and deterministic.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.sim.config import StaticConfig


def cta_issue(warp: dict, ctrl: dict, stats: dict, trace: dict,
              cfg: StaticConfig):
    """Dispatch CTAs to free warp slots.  Deliberately takes only the
    static config — no ``DynConfig``: dispatch depends on shape/capacity
    fields alone (none of the typed dynamic groups — core timing tables,
    cache/mem/icnt latencies — can affect WHICH warp slots fill), so a
    vmapped config sweep (core/sweep.py) shares this logic across lanes
    with no per-lane dynamic inputs."""
    ns, w = warp["active"].shape
    n_instr = trace["n_instr"]
    wpc = trace["warps_per_cta"]

    # free slots of warps that finished (pc done, no outstanding loads)
    finished = warp["active"] & (warp["pc"] >= n_instr) & \
        (warp["pending"] == 0)
    active = warp["active"] & ~finished

    free = ~active
    free_cnt = jnp.sum(free, axis=1).astype(jnp.int32)
    cap = jnp.minimum(free_cnt // wpc, cfg.max_cta_per_sm)

    # BREADTH-FIRST round-robin over ORIGINAL SM ids starting at rr
    # (Accel-sim semantics, paper §4.2: "CTAs are distributed in a
    # round-robin fashion among the GPU SMs") — one CTA per SM per round.
    pos = (ctrl["sm_ids"] - ctrl["rr"]) % ns
    perm = jnp.argsort(pos)                       # sm positions in deal order
    remaining = jnp.maximum(trace["n_ctas"] - ctrl["next_cta"], 0)

    maxc = int(cfg.max_cta_per_sm)
    cta_grid = jnp.full((ns, maxc), -1, jnp.int32)
    assigned = jnp.zeros((), jnp.int32)
    for r in range(maxc):
        elig = cap > r
        elig_ord = elig[perm]
        rank_ord = jnp.cumsum(elig_ord).astype(jnp.int32) - 1
        rank = jnp.zeros((ns,), jnp.int32).at[perm].set(rank_ord)
        take_r = elig & (rank < remaining - assigned)
        cta_grid = cta_grid.at[:, r].set(
            jnp.where(take_r, ctrl["next_cta"] + assigned + rank, -1))
        assigned = assigned + jnp.sum(take_r, dtype=jnp.int32)
    alloc = jnp.sum(cta_grid >= 0, axis=1).astype(jnp.int32)

    new_warps = alloc * wpc                            # per sm
    slot_rank = jnp.cumsum(free, axis=1).astype(jnp.int32) - 1
    take = free & (slot_rank < new_warps[:, None])
    cta_of_slot = jnp.take_along_axis(
        cta_grid, jnp.clip(slot_rank // wpc, 0, maxc - 1), axis=1)

    t0 = ctrl["cycle"]
    warp = dict(
        warp,
        active=active | take,
        pc=jnp.where(take, 0, warp["pc"]),
        ready_at=jnp.where(take, t0, warp["ready_at"]),
        pending=jnp.where(take, 0, warp["pending"]),
        wait_mem=jnp.where(take, False, warp["wait_mem"]),
        wait_bar=jnp.where(take, False, warp["wait_bar"]),
        cta=jnp.where(take, cta_of_slot, warp["cta"]),
        wic=jnp.where(take, slot_rank % wpc, warp["wic"]),
    )
    issued = assigned
    ctrl = dict(ctrl,
                next_cta=ctrl["next_cta"] + issued,
                rr=(ctrl["rr"] + 1) % ns)
    stats = dict(stats, ctas_launched=stats["ctas_launched"] + issued)
    return warp, ctrl, stats
