"""SM phase — the parallel region (>93% of Accel-sim's runtime, Fig. 4).

``sm_quantum_single`` simulates ONE SM for Δ cycles touching only that SM's
state slice (warps, L1, its MSHR rows, its stats) — zero cross-SM data flow.
core/parallel.py runs it vectorized (vmap), serialized (lax.map — the
single-thread reference), or sharded (shard_map over the 'sm' mesh axis).

Per cycle, per sub-core: deliver resolved memory responses, pick an issuable
warp (GTO: greedy-then-oldest; or LRR), look up L1 on memory ops (miss ⇒
allocate an MSHR row that the memory phase will service next quantum),
update the scoreboard-lite dependency state and the per-SM stats.

Config threading: every function takes the hashable ``StaticConfig`` (shape
decisions: array sizes, loop bounds, sub-core count) plus the typed
``DynConfig`` pytree of traced timing parameters — including the per-class
result-latency (``dyn.core.lat``) and dispatch-interval (``dyn.core.disp``)
tables, which are indexed as traced arrays here, never baked in as module
constants.  Nothing numeric is closed over as a Python constant, so the
whole SM phase vmaps over a batch of dynamic configs (core/sweep.py) —
per-class timing included.  Only the class→unit port mapping
(``UNIT_OF_CLASS``) stays static: it is structural, not a timing numeric.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sim.config import (BAR, LDG, SCHED_GTO, STG, DynConfig,
                              StaticConfig, UNIT_OF_CLASS)
from repro.sim.trace import gen_address

BIG = jnp.int32(1 << 30)


def _deliver(warp, req, t):
    """Deliver resolved responses for this SM. req fields: (M,)."""
    done = (req["stage"] == 3) & (req["t"] <= t)
    dec = jnp.zeros_like(warp["pending"]).at[req["warp"]].add(
        jnp.where(done & ~req["is_store"], 1, 0))
    warp = dict(warp, pending=warp["pending"] - dec)
    req = dict(req, stage=jnp.where(done, 0, req["stage"]))
    return warp, req


def _release_barriers(warp, n_instr, t):
    """CTA barrier: a waiting warp resumes once every active warp of its
    CTA has either arrived at the barrier or finished the kernel (uniform
    control flow — all warps execute the same trace).  Pairwise over the
    warp slots of one SM: O(W²) booleans, entirely SM-local."""
    cta = warp["cta"]
    active = warp["active"]
    arrived = warp["wait_bar"] | (warp["pc"] >= n_instr)
    same = active[None, :] & (cta[:, None] == cta[None, :])   # (W, W)
    n_same = jnp.sum(same, axis=1)
    n_arr = jnp.sum(same & arrived[None, :], axis=1)
    release = warp["wait_bar"] & (n_arr == n_same)
    return dict(warp,
                wait_bar=jnp.where(release, False, warp["wait_bar"]),
                ready_at=jnp.where(release, t, warp["ready_at"]))


def _l1_access(sm, addr, t, cfg: StaticConfig):
    """One L1 probe for a scalar addr. Returns (hit, sm_state')."""
    st = (addr % cfg.l1_sets).astype(jnp.int32)
    ways = sm["l1_tag"][st]                       # (ways,)
    hit = jnp.any(ways == addr)
    hway = jnp.argmax(ways == addr)
    victim = jnp.argmin(sm["l1_lru"][st])
    way = jnp.where(hit, hway, victim)
    l1_tag = sm["l1_tag"].at[st, way].set(
        jnp.where(hit, sm["l1_tag"][st, way], addr))
    l1_lru = sm["l1_lru"].at[st, way].set(t)
    return hit, dict(sm, l1_tag=l1_tag, l1_lru=l1_lru)


def _addrset_insert(sm, addr, enable, cfg: StaticConfig):
    """Bounded open-addressing set insert (the paper's set-valued stat,
    'per-SM instance + terminal union' strategy)."""
    cap = cfg.addrset_cap
    aset = sm["addrset"]
    idx = (addr.astype(jnp.uint32) * jnp.uint32(2654435761)
           % jnp.uint32(cap)).astype(jnp.int32)
    inserted = ~enable            # nothing to do when disabled
    over = jnp.zeros((), jnp.int32)
    for probe in range(4):
        slot = (idx + probe) % cap
        cur = aset[slot]
        can = (~inserted) & ((cur == addr) | (cur == -1))
        aset = aset.at[slot].set(jnp.where(can & (cur == -1), addr, cur))
        inserted = inserted | can
    over = jnp.where(~inserted, 1, 0)
    return dict(sm, addrset=aset,
                addrset_over=sm["addrset_over"] + over)


def _issue_subcore(warp, sm, req, stats, trace, t, sc, cfg: StaticConfig,
                   dyn: DynConfig):
    """Issue at most one instruction on sub-core `sc` (single SM view)."""
    nsc = cfg.n_subcores
    w_ids = jnp.arange(sc, cfg.warps_per_sm, nsc, dtype=jnp.int32)
    pc = warp["pc"][w_ids]
    active = warp["active"][w_ids]
    n_instr = trace["n_instr"]
    exists = active & (pc < n_instr)
    blocked = (warp["wait_mem"][w_ids] & (warp["pending"][w_ids] > 0)) \
        | warp["wait_bar"][w_ids]
    ready = exists & ~blocked & (warp["ready_at"][w_ids] <= t)

    # ragged layout (core/batch.py:concat_kernels): instruction arrays are
    # flat across kernels; fetch at instr_base + pc.  pc itself STAYS
    # kernel-local — address generation hashes it, so offsetting pc would
    # change simulated addresses and break bit-exactness vs padded runs.
    base = trace["instr_base"] if "instr_base" in trace else 0
    pcc = jnp.clip(pc, 0, n_instr - 1)
    op = trace["ops"][base + pcc]
    unit = jnp.asarray(UNIT_OF_CLASS, jnp.int32)[op]
    ufree = sm["unit_free"][sc][unit] <= t
    is_mem = (op == LDG) | (op == STG)
    free_rows = jnp.sum(req["stage"] == 0) > 0
    cand = ready & ufree & (~is_mem | free_rows)

    # scheduler: GTO (greedy warp first, then oldest) or loose round-robin.
    # The selector is a traced value so one compiled program serves both —
    # and a vmapped sweep can mix GTO and LRR lanes.
    greedy = w_ids == sm["last_issued"][sc]
    key_gto = jnp.where(greedy, -1, w_ids)
    key_lrr = (w_ids - sm["last_issued"][sc] - 1) % cfg.warps_per_sm
    key = jnp.where(dyn.core.sched == SCHED_GTO, key_gto, key_lrr)
    key = jnp.where(cand, key, BIG)
    sel = jnp.argmin(key)
    do = cand[sel]
    wsel = w_ids[sel]                   # global warp slot
    spc = pcc[sel]
    sop = op[sel]
    sunit = unit[sel]

    # ---- memory handling ---------------------------------------------------
    gwarp = warp["cta"][wsel] * trace["warps_per_cta"] + warp["wic"][wsel]
    addr = gen_address(trace["addr_mode"][base + spc],
                       trace["addr_param"][base + spc],
                       gwarp, spc, cfg.mem_blocks)
    mem_issue = do & (sop == LDG) | (do & (sop == STG))
    hit, sm_new = _l1_access(sm, addr, t, cfg)
    sm = jax.tree_util.tree_map(
        lambda a, b: jnp.where(mem_issue, b, a), sm, sm_new)
    sm = _addrset_insert(sm, addr, mem_issue, cfg)
    l1_hit = mem_issue & hit
    l1_miss = mem_issue & ~hit

    # MSHR allocation on miss
    row = jnp.argmin(jnp.where(req["stage"] == 0, 0, 1))
    alloc = l1_miss
    req = dict(
        req,
        stage=req["stage"].at[row].set(
            jnp.where(alloc, 1, req["stage"][row])),
        addr=req["addr"].at[row].set(
            jnp.where(alloc, addr, req["addr"][row])),
        t=req["t"].at[row].set(
            jnp.where(alloc, t + dyn.icnt.icnt_lat, req["t"][row])),
        warp=req["warp"].at[row].set(
            jnp.where(alloc, wsel, req["warp"][row])),
        is_store=req["is_store"].at[row].set(
            jnp.where(alloc, sop == STG, req["is_store"][row])),
    )

    # ---- dependency / latency ----------------------------------------------
    lat = dyn.core.lat[sop]
    lat = jnp.where(sop == LDG, jnp.where(hit, dyn.cache.l1_hit_lat, 1), lat)
    dep_next = jnp.where(spc + 1 < n_instr, trace["dep"][
        base + jnp.clip(spc + 1, 0, n_instr - 1)], False)
    wait_lat = jnp.where(dep_next, jnp.maximum(lat, 1), 1)
    new_ready = t + wait_lat
    new_wait = dep_next & l1_miss          # wait on outstanding loads
    new_pending = warp["pending"][wsel] + jnp.where(
        l1_miss & (sop == LDG), 1, 0)

    warp = dict(
        warp,
        pc=warp["pc"].at[wsel].set(jnp.where(do, spc + 1, warp["pc"][wsel])),
        ready_at=warp["ready_at"].at[wsel].set(
            jnp.where(do, new_ready, warp["ready_at"][wsel])),
        wait_mem=warp["wait_mem"].at[wsel].set(
            jnp.where(do, new_wait, warp["wait_mem"][wsel])),
        wait_bar=warp["wait_bar"].at[wsel].set(
            jnp.where(do & (sop == BAR), True, warp["wait_bar"][wsel])),
        pending=warp["pending"].at[wsel].set(
            jnp.where(do, new_pending, warp["pending"][wsel])),
    )
    disp = dyn.core.disp[sop]
    sm = dict(
        sm,
        unit_free=sm["unit_free"].at[sc, sunit].set(
            jnp.where(do, t + disp, sm["unit_free"][sc, sunit])),
        last_issued=sm["last_issued"].at[sc].set(
            jnp.where(do, wsel, sm["last_issued"][sc])),
    )
    stats = dict(
        stats,
        issued=stats["issued"] + jnp.where(do, 1, 0),
        issued_mem=stats["issued_mem"] + jnp.where(mem_issue, 1, 0),
        l1_hit=stats["l1_hit"] + jnp.where(l1_hit, 1, 0),
        l1_miss=stats["l1_miss"] + jnp.where(l1_miss, 1, 0),
        stall=stats["stall"] + jnp.where(jnp.any(exists) & ~do, 1, 0),
    )
    return warp, sm, req, stats, do


def sm_cycle_single(warp, sm, req, stats, trace, t, cfg: StaticConfig,
                    dyn: DynConfig):
    """One cycle of one SM (arrays without the n_sm axis)."""
    warp, req = _deliver(warp, req, t)
    warp = _release_barriers(warp, trace["n_instr"], t)
    issued_any = jnp.zeros((), jnp.bool_)
    for sc in range(cfg.n_subcores):
        warp, sm, req, stats, did = _issue_subcore(
            warp, sm, req, stats, trace, t, sc, cfg, dyn)
        issued_any = issued_any | did
    stats = dict(
        stats,
        cycles_issue=stats["cycles_issue"] + jnp.where(issued_any, 1, 0),
        warp_cycles=stats["warp_cycles"]
        + jnp.sum(warp["active"], dtype=jnp.int32),
    )
    return warp, sm, req, stats


def sm_quantum_single(warp, sm, req, stats, trace, t0, cfg: StaticConfig,
                      dyn: DynConfig):
    """Run Δ consecutive cycles for one SM — the communication window."""
    def body(i, carry):
        warp, sm, req, stats = carry
        return sm_cycle_single(warp, sm, req, stats, trace, t0 + i, cfg, dyn)

    return jax.lax.fori_loop(0, cfg.quantum, body, (warp, sm, req, stats))
