"""Table-2 benchmark analogues.

Accel-sim replays SASS traces of the real binaries; those traces are not
shippable here, so each suite entry is a *synthetic trace generator* tuned
to the structural properties the paper reports or that follow from the
app's algorithm: CTAs/kernel (Fig. 7 — myocyte=2, lavaMD ≫ 80, cut_1 small),
kernel counts, instruction mix, dependence density and address pattern
(streaming stencils vs. irregular graph traversal vs. tensor-core GEMM
tiles).  ``scale`` shrinks CTA counts/trace lengths uniformly so the full
suite simulates in minutes on one CPU core; relative behaviour (Fig. 5/6/7
shapes) is preserved.
"""
from __future__ import annotations

import numpy as np

from repro.sim.config import BAR, FP32, INT32, LDG, SFU, STG, TENSOR
from repro.sim.trace import (A_RANDOM, A_STREAM, A_STRIDED, KernelTrace,
                             Workload, build_kernel)


def _body_compute(n_fp=8, n_sfu=0, dep_every=3, param=0):
    body = []
    for i in range(n_fp):
        body.append((FP32, i % dep_every == 0, 0, 0))
    for i in range(n_sfu):
        body.append((SFU, True, 0, 0))
    return body


def _body_stream(n_ld=4, n_fp=6, param=0, store=True):
    body = [(LDG, False, A_STREAM, param + i) for i in range(n_ld)]
    body += [(FP32, i == 0, 0, 0) for i in range(n_fp)]
    if store:
        body.append((STG, False, A_STREAM, param + 7))
    return body


def _body_irregular(n_ld=4, n_int=6, param=0):
    body = []
    for i in range(n_ld):
        body.append((LDG, i > 0, A_RANDOM, param + i))
        body.append((INT32, True, 0, 0))
    body += [(INT32, False, 0, 0)] * n_int
    return body


def _body_gemm_tile(k_steps=4, param=0):
    body = []
    for i in range(k_steps):
        body.append((LDG, False, A_STRIDED, param + i))
        body.append((LDG, False, A_STRIDED, param + 64 + i))
        body.append((TENSOR, True, 0, 0))
        body.append((TENSOR, True, 0, 0))
    body.append((STG, False, A_STREAM, param))
    return body


def _s(n, scale):  # scaled CTA count, at least 1
    return max(1, int(round(n * scale)))


def make_workload(name: str, scale: float = 1.0) -> Workload:  # noqa: C901
    w = Workload(name)
    add = w.kernels.append
    if name == "gaussian":
        for i in range(24):
            n = _s(max(4, 256 - 10 * i), scale)
            add(build_kernel(f"fan{i}", n_ctas=n, warps_per_cta=2,
                             body=_body_stream(2, 4, param=i), repeats=2))
    elif name == "hotspot":
        for it in range(4):
            add(build_kernel(f"step{it}", n_ctas=_s(1024, scale),
                             warps_per_cta=4,
                             body=_body_stream(5, 12, param=it), repeats=2))
    elif name == "hybridsort":
        add(build_kernel("hist", n_ctas=_s(256, scale), warps_per_cta=4,
                         body=_body_irregular(3, 4), repeats=3))
        for i in range(4):
            add(build_kernel(f"bucket{i}", n_ctas=_s(128, scale),
                             warps_per_cta=4,
                             body=_body_irregular(4, 6, param=i), repeats=2))
    elif name == "lavaMD":
        add(build_kernel("kcal", n_ctas=_s(4096, scale), warps_per_cta=4,
                         body=_body_compute(24, 8) + _body_stream(2, 8),
                         repeats=4))
    elif name == "lud":
        for i in range(16):
            n = _s(max(2, 128 - 8 * i), scale)
            add(build_kernel(f"diag{i}", n_ctas=n, warps_per_cta=2,
                             body=_body_stream(3, 8, param=i), repeats=2))
    elif name == "myocyte":
        # the paper's pathological case: 2 CTAs per kernel
        add(build_kernel("solver", n_ctas=2, warps_per_cta=4,
                         body=_body_compute(16, 8, dep_every=2)
                         + _body_stream(2, 8), repeats=24))
    elif name == "nn":
        add(build_kernel("dist", n_ctas=_s(168, scale), warps_per_cta=4,
                         body=_body_stream(3, 4), repeats=2))
    elif name == "nw":
        for i in range(12):
            n = _s(min(i + 1, 12 - i) * 16, scale)
            add(build_kernel(f"wave{i}", n_ctas=max(n, 1), warps_per_cta=2,
                             body=_body_stream(3, 6, param=i)))
    elif name == "pathfinder":
        for it in range(3):
            add(build_kernel(f"row{it}", n_ctas=_s(463, scale),
                             warps_per_cta=4,
                             body=_body_stream(3, 6, param=it), repeats=2))
    elif name == "srad":
        for it in range(3):
            add(build_kernel(f"srad1_{it}", n_ctas=_s(512, scale),
                             warps_per_cta=4,
                             body=_body_stream(4, 10, param=it) +
                             [(SFU, True, 0, 0)], repeats=2))
    elif name == "fdtd2d":
        for it in range(3):
            for f in range(3):
                add(build_kernel(f"f{f}_{it}", n_ctas=_s(708, scale),
                                 warps_per_cta=4,
                                 body=_body_stream(4, 8, param=f)))
    elif name == "syrk":
        add(build_kernel("syrk", n_ctas=_s(512, scale), warps_per_cta=4,
                         body=_body_gemm_tile(6), repeats=2))
    elif name == "mst":
        for it in range(12):
            add(build_kernel(f"find{it}", n_ctas=_s(192, scale),
                             warps_per_cta=4,
                             body=_body_irregular(5, 8, param=it),
                             repeats=2))
    elif name == "sssp":
        sizes = [8, 32, 128, 384, 512, 384, 160, 64, 24, 8]
        for it, n in enumerate(sizes):
            add(build_kernel(f"relax{it}", n_ctas=_s(n, scale),
                             warps_per_cta=4,
                             body=_body_irregular(5, 6, param=it),
                             repeats=2))
    elif name == "conv":
        add(build_kernel("im2col", n_ctas=_s(1568, scale), warps_per_cta=4,
                         body=_body_stream(4, 4)))
        add(build_kernel("gemm", n_ctas=_s(1024, scale), warps_per_cta=4,
                         body=_body_gemm_tile(6), repeats=2))
    elif name == "gemm":
        add(build_kernel("gemm", n_ctas=_s(1600, scale), warps_per_cta=4,
                         body=_body_gemm_tile(8), repeats=2))
    elif name == "rnn":
        for t in range(16):
            add(build_kernel(f"cell{t}", n_ctas=_s(64, scale),
                             warps_per_cta=4,
                             body=_body_gemm_tile(4, param=t)))
    elif name == "cut_1":
        # 2560×16×2560 tiles → few CTAs (paper: dynamic scheduler wins)
        add(build_kernel("cutlass", n_ctas=_s(20, max(scale, 1.0)),
                         warps_per_cta=8, body=_body_gemm_tile(20),
                         repeats=2))
    elif name == "cut_2":
        add(build_kernel("cutlass", n_ctas=_s(160, scale), warps_per_cta=8,
                         body=_body_gemm_tile(20), repeats=2))
    elif name == "stencil_bar":
        # shared-memory-style stencil with CTA barriers between phases
        body = (_body_stream(3, 6)
                + [(BAR, False, 0, 0)]
                + _body_compute(8)
                + [(BAR, False, 0, 0)]
                + _body_stream(2, 4))
        add(build_kernel("stencil", n_ctas=_s(512, scale), warps_per_cta=4,
                         body=body, repeats=3))
    else:
        raise KeyError(name)
    return w


SUITES = {
    "rodinia": ["gaussian", "hotspot", "hybridsort", "lavaMD", "lud",
                "myocyte", "nn", "nw", "pathfinder", "srad"],
    "polybench": ["fdtd2d", "syrk"],
    "lonestar": ["mst", "sssp"],
    "deepbench": ["conv", "gemm", "rnn"],
    "cutlass": ["cut_1", "cut_2"],
}

ALL_BENCHMARKS = [b for s in SUITES.values() for b in s]
