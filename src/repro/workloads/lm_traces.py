"""LM-derived simulator workloads — the assigned architectures as kernels.

The paper's technique applied first-class: every (arch × shape) cell can be
converted into a GPU kernel trace (per-layer GEMM tiles, attention tiles,
MoE dispatch, recurrence chunks) and simulated on the modeled GPU with the
deterministic parallel engine.  One representative layer is traced and
scaled (tokens ÷ ``token_div``, CTAs capped) so cells simulate in seconds;
the mapping is documented per family below.
"""
from __future__ import annotations

import math

from repro.configs.base import ArchConfig, ShapeSpec
from repro.sim.trace import Workload, build_kernel
from repro.workloads.synthetic import (_body_gemm_tile, _body_irregular,
                                       _body_stream)

TILE = 128
CTA_CAP = 4096


def _gemm_kernel(name, m, n, k, warps=4):
    ctas = min(CTA_CAP, max(1, math.ceil(m / TILE) * math.ceil(n / TILE)))
    ksteps = min(32, max(1, k // TILE))
    return build_kernel(name, n_ctas=ctas, warps_per_cta=warps,
                        body=_body_gemm_tile(ksteps))


def arch_workload(cfg: ArchConfig, shape: ShapeSpec,
                  token_div: int = 64) -> Workload:
    """One representative transformer layer of `cfg` under `shape`."""
    w = Workload(f"{cfg.name}__{shape.name}")
    add = w.kernels.append
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if shape.is_decode:
        tokens = max(1, shape.global_batch)
    else:
        tokens = max(1, shape.tokens // token_div)

    # attention / mixer
    if cfg.family == "ssm":
        # rwkv: chunked linear attention — CTAs = B×H chunk-scans
        add(_gemm_kernel("proj_rkvg", tokens, 4 * d, d))
        chunks = max(1, min(CTA_CAP, tokens // 64))
        add(build_kernel("wkv_chunk", n_ctas=chunks, warps_per_cta=2,
                         body=_body_stream(4, 24, store=True), repeats=2))
        add(_gemm_kernel("out_proj", tokens, d, d))
    else:
        qkv_out = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        add(_gemm_kernel("qkv_proj", tokens, qkv_out, d))
        if shape.is_decode:
            # decode attention: stream the KV cache
            ctas = min(CTA_CAP,
                       max(1, shape.global_batch * cfg.n_kv_heads))
            add(build_kernel("attn_decode", n_ctas=ctas, warps_per_cta=4,
                             body=_body_stream(8, 8, store=False),
                             repeats=4))
        else:
            s_tiles = max(1, (shape.seq_len // token_div) // TILE)
            ctas = min(CTA_CAP, max(1, cfg.n_heads * s_tiles))
            add(build_kernel("attn_tiles", n_ctas=ctas, warps_per_cta=4,
                             body=_body_gemm_tile(8), repeats=2))
        add(_gemm_kernel("o_proj", tokens, d, cfg.n_heads * hd))

    # FFN / MoE
    if cfg.moe is not None:
        add(build_kernel("moe_route", n_ctas=min(CTA_CAP,
                                                 max(1, tokens // 256)),
                         warps_per_cta=4, body=_body_irregular(4, 8)))
        e_tokens = max(1, tokens * cfg.moe.top_k // cfg.moe.n_experts)
        for proj, (m, n, k) in {
                "expert_up": (e_tokens * min(cfg.moe.n_experts, 16),
                              cfg.moe.d_ff_expert, d),
                "expert_down": (e_tokens * min(cfg.moe.n_experts, 16), d,
                                cfg.moe.d_ff_expert)}.items():
            add(_gemm_kernel(proj, m, n, k))
    else:
        add(_gemm_kernel("ffn_up", tokens, cfg.d_ff, d))
        add(_gemm_kernel("ffn_down", tokens, d, cfg.d_ff))
    if cfg.block_pattern is not None:
        # jamba: one mamba sublayer (conv + chunked scan)
        di = cfg.ssm.expand * d
        add(_gemm_kernel("mamba_in", tokens, 2 * di, d))
        chunks = max(1, min(CTA_CAP, tokens // 64))
        add(build_kernel("ssm_chunk", n_ctas=chunks, warps_per_cta=2,
                         body=_body_stream(4, 20), repeats=2))
        add(_gemm_kernel("mamba_out", tokens, d, di))
    return w
