from repro.sim.workloads import zoo_names, zoo_workload
from repro.workloads.lm_traces import arch_workload
from repro.workloads.synthetic import ALL_BENCHMARKS, SUITES, make_workload

__all__ = ["ALL_BENCHMARKS", "SUITES", "make_workload", "arch_workload",
           "zoo_names", "zoo_workload"]
