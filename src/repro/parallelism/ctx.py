"""Sharding context threaded through model code.

Model code is written once, globally; ``ShardCtx`` carries the mesh axis
names so layers can drop ``with_sharding_constraint`` hints.  With no mesh
(CPU smoke tests) every hint is a no-op.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import reduce
from operator import mul
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Mesh]
    batch_axes: tuple = ()          # ('pod', 'data') / ('data',) / ()
    tp_axis: Optional[str] = None   # 'model'

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return reduce(mul, (self.mesh.shape[a] for a in self.batch_axes), 1)

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    # ---- axis helpers ------------------------------------------------------
    def tp_if(self, n: int):
        """'model' if the tp axis evenly divides n, else replicated."""
        if self.tp_axis is not None and n % self.tp_size == 0 and self.tp_size > 1:
            return self.tp_axis
        return None

    def dp_if(self, n: int):
        if self.batch_axes and n % self.dp_size == 0:
            return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        return None

    def ep_axes(self, n_experts: int, d_ff: int):
        """Expert-parallel placement: (expert_axis, ff_axis).

        Preference order:
          1. experts over dp, ff over tp    -> 2-D expert sharding.  The
             token->expert reshard stays within the data axes (a sharding
             transpose SPMD lowers to an all-to-all); sharding experts over
             (data×model) combined instead hits SPMD's "involuntary full
             rematerialization" path (b/433785288) and replicates the
             dispatch buffer.
          2. experts over (dp+tp) combined  -> fully sharded experts
          3. experts over tp                -> classic EP
          4. replicated
        """
        dp, tp = self.dp_size, self.tp_size
        if self.mesh is None:
            return None, None
        all_axes = tuple(self.batch_axes) + ((self.tp_axis,) if self.tp_axis else ())
        if dp > 1 and n_experts % dp == 0 and self.tp_axis and d_ff % tp == 0:
            ba = self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
            return ba, self.tp_axis
        if dp * tp > 1 and n_experts % (dp * tp) == 0:
            return all_axes, None
        if self.tp_axis and n_experts % tp == 0:
            return self.tp_axis, None
        return None, None

    # ---- constraint hint ---------------------------------------------------
    def hint(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def batch(self):
        """Spec entry for a batch-sharded leading dim."""
        if not self.batch_axes:
            return None
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]


NULL_CTX = ShardCtx(mesh=None)
