"""Path-based PartitionSpec rules for every parameter / batch / cache leaf.

The rules implement:
  TP   — Megatron column/row splits; head-axis TP when n_heads % tp == 0,
         head_dim TP otherwise (block-local RoPE makes this legal).
  EP   — expert placement via ShardCtx.ep_axes (full / 2-D / tp-only).
  DP   — batch leading axes over ('pod','data').
  SP   — decode caches shard the *sequence* axis over the data axes when the
         batch axis is too small (long_500k, global_batch=1).
  ZeRO-1 — optimizer moments additionally sharded over the data axes.

Every leaf must match a rule: unmatched leaves raise, and a test asserts
full coverage over all ten architectures.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ArchConfig
from repro.models.layers.attention import head_axes
from repro.parallelism.ctx import ShardCtx

_NORM_PARENTS = {"attn_norm", "mlp_norm", "final_norm", "ln1", "ln2", "norm",
                 "q_norm", "kv_norm", "self_norm", "cross_norm", "enc_norm",
                 "dec_norm"}
_FFN_PARENTS = {"mlp", "shared", "dense"}
_ATTN_PARENTS = {"attn", "self_attn", "cross_attn"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
    return names


def _param_rule(names: list[str], shape, cfg: ArchConfig, ctx: ShardCtx):
    """Spec for the *trailing* dims; caller pads leading stacked dims."""
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    tp = ctx.tp_if
    hd = cfg.resolved_head_dim
    h_ax, hd_ax = head_axes(ctx, cfg.n_heads, hd)
    kv_h_ax = h_ax if (h_ax and cfg.n_kv_heads % ctx.tp_size == 0) else None

    if parent in _NORM_PARENTS or name in ("scale", "bias"):
        return (None,) * 1 if len(shape) >= 1 else ()
    if parent == "embed" and name == "emb":
        return (None, tp(cfg.d_model))
    if parent == "head" and name == "w":
        return (None, tp(cfg.padded_vocab(32)))
    if name == "pos_dec":
        return (None, None)
    if parent in _ATTN_PARENTS:
        return {
            "wq": (None, h_ax, hd_ax),
            "wk": (None, kv_h_ax, hd_ax),
            "wv": (None, kv_h_ax, hd_ax),
            "wo": (h_ax, hd_ax, None),
            "bq": (h_ax, hd_ax),
            "bk": (kv_h_ax, hd_ax),
            "bv": (kv_h_ax, hd_ax),
        }[name]
    if parent == "mla":
        th = tp(cfg.n_heads)
        return {
            "wdq": (None, None), "wdkv": (None, None),
            "wuq": (None, th, None), "wuk": (None, th, None),
            "wuv": (None, th, None), "wo": (th, None, None),
        }[name]
    if parent == "moe":
        ep_ax, ff_ax = ctx.ep_axes(cfg.moe.n_experts, cfg.moe.d_ff_expert)
        return {
            "router": (None, None),
            "wi_gate": (ep_ax, None, ff_ax),
            "wi_up": (ep_ax, None, ff_ax),
            "wo": (ep_ax, ff_ax, None),
        }[name]
    if parent in _FFN_PARENTS:
        if name in ("wi_gate", "wi_up", "wi"):
            return (None, tp(shape[-1]))
        if name == "wo":
            return (tp(shape[-2]), None)
    if parent == "tm":
        d = cfg.d_model
        return {
            "wr": (None, tp(d)), "wk": (None, tp(d)), "wv": (None, tp(d)),
            "wg": (None, tp(d)), "wo": (tp(d), None),
            "wd1": (None, None), "wd2": (None, tp(d)),
            "w0": (tp(d),), "u": (tp(d),),
            "gn_scale": (tp(d),), "gn_bias": (tp(d),),
            "mu_x": (None,), "mu": (None, None),
            "mix_w1": (None, None), "mix_w2": (None, None, None),
        }[name]
    if parent == "cm":
        return {
            "wk": (None, tp(cfg.d_ff)), "wv": (tp(cfg.d_ff), None),
            "wr": (None, None), "mu_k": (None,), "mu_r": (None,),
        }[name]
    if parent == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        return {
            "wx": (None, tp(di)), "wz": (None, tp(di)),
            "conv_w": (None, tp(di)), "conv_b": (tp(di),),
            "wxp": (tp(di), None), "wdt": (None, tp(di)),
            "dt_bias": (tp(di),), "A_log": (tp(di), None),
            "D": (tp(di),), "wo": (tp(di), None),
        }[name]
    raise KeyError(f"no sharding rule for param path {'/'.join(names)} "
                   f"shape={tuple(shape)}")


def _pad(rule: tuple, ndim: int) -> P:
    if len(rule) > ndim:
        # scalar-ish leaves (e.g. 1-element rule on 0-d) — replicate
        rule = rule[-ndim:] if ndim else ()
    return P(*((None,) * (ndim - len(rule)) + tuple(rule)))


def param_pspecs(params, cfg: ArchConfig, ctx: ShardCtx):
    def leaf(path, x):
        names = _path_names(path)
        return _pad(_param_rule(names, x.shape, cfg, ctx), len(x.shape))
    return jax.tree_util.tree_map_with_path(leaf, params)


# ---------------------------------------------------------------------------
# batches / caches / logits
# ---------------------------------------------------------------------------

def batch_pspecs(batch, ctx: ShardCtx):
    def leaf(x):
        b = x.shape[0]
        # DP on the leading (batch) dim, everything else replicated
        return P(ctx.dp_if(b), *((None,) * (len(x.shape) - 1)))
    return jax.tree_util.tree_map(leaf, batch)


def cache_pspecs(cache, cfg: ArchConfig, ctx: ShardCtx):
    hd = cfg.resolved_head_dim
    h_ax, hd_ax = head_axes(ctx, cfg.n_heads, hd)
    kv_h_ax = h_ax if (h_ax and cfg.n_kv_heads % ctx.tp_size == 0) else None

    def seq_entry(b, s, model_used: bool):
        """(B_ax, S_ax).  Batch over data; the sequence axis picks up every
        mesh axis not already used (model, or data+model when B=1) so the
        cache — the dominant decode state — is maximally sharded."""
        b_ax = ctx.dp_if(b)
        if b_ax is not None:
            s_ax = None if model_used else ctx.tp_if(s)
            return b_ax, s_ax
        # tiny batch (long_500k): shard the sequence instead
        if not model_used and ctx.batch_axes and ctx.tp_axis and \
                s % (ctx.dp_size * ctx.tp_size) == 0:
            return None, tuple(ctx.batch_axes) + (ctx.tp_axis,)
        return None, ctx.dp_if(s)

    def leaf(path, x):
        names = _path_names(path)
        name = names[-1]
        sh = x.shape
        if name == "len":
            return P(None)
        if name in ("k", "v", "ck", "cv"):
            n, b, s = sh[0], sh[1], sh[2]
            model_used = (kv_h_ax is not None) or (hd_ax is not None)
            b_ax, s_ax = seq_entry(b, s, model_used)
            return P(None, b_ax, s_ax, kv_h_ax, hd_ax)
        if name in ("ckv", "kr"):
            b_ax, s_ax = seq_entry(sh[1], sh[2], False)
            return P(None, b_ax, s_ax, None)
        if name == "S":      # rwkv state (n,B,H,hs,hs)
            return P(None, ctx.dp_if(sh[1]), ctx.tp_if(sh[2]), None, None)
        if name in ("tm", "cm"):
            return P(None, ctx.dp_if(sh[1]), None)
        if name == "h":      # mamba (n,nm,B,di,ds)
            return P(None, None, ctx.dp_if(sh[2]), ctx.tp_if(sh[3]), None)
        if name == "conv":   # (n,nm,B,K-1,di)
            return P(None, None, ctx.dp_if(sh[2]), None, ctx.tp_if(sh[4]))
        raise KeyError(f"no cache rule for {'/'.join(names)}")
    return jax.tree_util.tree_map_with_path(leaf, cache)


def logits_pspec(cfg: ArchConfig, ctx: ShardCtx, batch: int):
    return P(ctx.dp_if(batch), ctx.tp_if(cfg.padded_vocab(32)))


# ---------------------------------------------------------------------------
# ZeRO-1: moments additionally sharded over the data axes
# ---------------------------------------------------------------------------

def zero1_pspec(spec: P, shape, ctx: ShardCtx):
    if not ctx.batch_axes:
        return spec
    used = set()
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(a)
    if any(a in used for a in ctx.batch_axes):
        return spec
    dp = ctx.dp_size
    entries = list(spec)
    for i, (entry, dim) in enumerate(zip(entries, shape)):
        if entry is None and dim % dp == 0 and dim >= dp:
            entries[i] = (ctx.batch_axes if len(ctx.batch_axes) > 1
                          else ctx.batch_axes[0])
            return P(*entries)
    return spec


def moments_pspecs(param_specs, params, ctx: ShardCtx):
    return jax.tree_util.tree_map(
        lambda s, x: zero1_pspec(s, x.shape, ctx), param_specs, params)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
