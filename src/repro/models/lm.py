"""Decoder-LM assembly for all assigned families.

An architecture is a list of *groups*; each group is `count` structurally
identical blocks whose parameters are stacked on a leading layer axis and
executed with `lax.scan` (+ per-block remat).  Heterogeneous stacks
(deepseek dense-prefix, jamba periods) are expressed as multiple groups /
period-internal python loops, keeping the HLO small enough to compile the
full 61-80 layer models for 512 devices.

Group kinds:
  'std:dense' / 'std:moe'  — GQA attention + (dense | MoE) FFN
  'mla:dense' / 'mla:moe'  — DeepSeek MLA + (dense | MoE) FFN
  'rwkv'                   — RWKV-6 time-mix + channel-mix
  'period'                 — jamba 8-sublayer period (attn@4, MoE on odd)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import attention as attn
from repro.models.layers import mamba as mam
from repro.models.layers import mla as mla_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers import rwkv6 as rwkv
from repro.models.layers.common import apply_norm, init_norm
from repro.models.layers.ffn import apply_ffn, init_ffn
from repro.models.layers.rope import text_mrope_positions
from repro.parallelism.ctx import NULL_CTX, ShardCtx

VOCAB_PAD = 32


# ---------------------------------------------------------------------------
# architecture -> group plan
# ---------------------------------------------------------------------------

def group_plan(cfg: ArchConfig) -> list[tuple[str, int]]:
    if cfg.block_pattern is not None:
        period = len(cfg.block_pattern)
        assert cfg.n_layers % period == 0
        return [("period", cfg.n_layers // period)]
    if cfg.family == "ssm":
        return [("rwkv", cfg.n_layers)]
    attn_kind = "mla" if cfg.mla is not None else "std"
    if cfg.moe is None:
        return [(f"{attn_kind}:dense", cfg.n_layers)]
    if cfg.moe.layer_mode == "after_prefix":
        return [(f"{attn_kind}:dense", cfg.n_dense_prefix),
                (f"{attn_kind}:moe", cfg.n_layers - cfg.n_dense_prefix)]
    return [(f"{attn_kind}:moe", cfg.n_layers)]


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _init_block(key, kind: str, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 20)
    if kind == "rwkv":
        return {
            "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
            "tm": rwkv.init_time_mix(ks[0], cfg, dtype),
            "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
            "cm": rwkv.init_channel_mix(ks[1], cfg, dtype),
        }
    if kind == "period":
        p = {}
        for i, sub in enumerate(cfg.block_pattern):
            mixer = (attn.init_attention(ks[2 * i], cfg, dtype)
                     if sub == "attn" else mam.init_mamba(ks[2 * i], cfg, dtype))
            is_moe = cfg.moe is not None and i % 2 == 1
            mlp = (moe_mod.init_moe(ks[2 * i + 1], cfg, dtype) if is_moe
                   else init_ffn(ks[2 * i + 1], cfg.d_model, cfg.d_ff,
                                 cfg.act, dtype))
            p[f"sub{i}"] = {
                "norm": init_norm(cfg.norm, cfg.d_model, dtype),
                ("attn" if sub == "attn" else "mamba"): mixer,
                "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
                ("moe" if is_moe else "mlp"): mlp,
            }
        return p
    attn_kind, mlp_kind = kind.split(":")
    mixer = (mla_mod.init_mla(ks[0], cfg, dtype) if attn_kind == "mla"
             else attn.init_attention(ks[0], cfg, dtype))
    mlp = (moe_mod.init_moe(ks[1], cfg, dtype) if mlp_kind == "moe"
           else init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype))
    return {
        "attn_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        ("attn" if attn_kind == "std" else "mla"): mixer,
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        ("moe" if mlp_kind == "moe" else "mlp"): mlp,
    }


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    vp = cfg.padded_vocab(VOCAB_PAD)
    keys = jax.random.split(key, 3 + len(group_plan(cfg)))
    params = {
        "embed": {"emb": (0.02 * jax.random.normal(
            keys[0], (vp, cfg.d_model))).astype(dtype)},
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": (cfg.d_model ** -0.5 * jax.random.normal(
            keys[1], (cfg.d_model, vp))).astype(dtype)}
    groups = []
    for gi, (kind, count) in enumerate(group_plan(cfg)):
        gkeys = jax.random.split(keys[3 + gi], count)
        groups.append(jax.vmap(
            partial(_init_block, kind=kind, cfg=cfg, dtype=dtype))(gkeys))
    params["groups"] = groups
    return params


# ---------------------------------------------------------------------------
# block apply — train (no cache)
# ---------------------------------------------------------------------------

def _mlp_or_moe(p, x, aux, cfg, ctx):
    if "moe" in p:
        y, a = moe_mod.apply_moe(p["moe"], x, cfg=cfg, ctx=ctx)
        return y, aux + a
    return apply_ffn(p["mlp"], x, act=cfg.act, ctx=ctx), aux


def _block_train(p, x, aux, *, kind: str, cfg: ArchConfig, ctx: ShardCtx,
                 positions):
    nk, eps = cfg.norm, cfg.norm_eps
    if kind == "rwkv":
        b, _, d = x.shape
        h = cfg.d_model // cfg.rwkv.head_size
        hs = cfg.rwkv.head_size
        zshift = jnp.zeros((b, d), x.dtype)
        zstate = jnp.zeros((b, h, hs, hs), jnp.float32)
        y, _, _ = rwkv.time_mix_train(
            p["tm"], apply_norm(p["ln1"], x, kind=nk, eps=eps),
            zshift, zstate, cfg=cfg, ctx=ctx)
        x = x + y
        y, _ = rwkv.channel_mix(
            p["cm"], apply_norm(p["ln2"], x, kind=nk, eps=eps),
            zshift, cfg=cfg, ctx=ctx)
        return x + y, aux
    if kind == "period":
        b, _, d = x.shape
        di = cfg.ssm.expand * d
        for i, sub in enumerate(cfg.block_pattern):
            sp = p[f"sub{i}"]
            hpre = apply_norm(sp["norm"], x, kind=nk, eps=eps)
            if sub == "attn":
                y = attn.attention_train(sp["attn"], hpre, cfg=cfg, ctx=ctx,
                                         positions=positions)
            else:
                zconv = jnp.zeros((b, cfg.ssm.d_conv - 1, di), x.dtype)
                zh = jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)
                y, _, _ = mam.mamba_train(sp["mamba"], hpre, zconv, zh,
                                          cfg=cfg, ctx=ctx)
            x = x + y
            hpre = apply_norm(sp["mlp_norm"], x, kind=nk, eps=eps)
            y, aux = _mlp_or_moe(sp, hpre, aux, cfg, ctx)
            x = x + y
        return x, aux
    # std / mla
    hpre = apply_norm(p["attn_norm"], x, kind=nk, eps=eps)
    if "mla" in p:
        y = mla_mod.mla_train(p["mla"], hpre, cfg=cfg, ctx=ctx,
                              positions=positions)
    else:
        y = attn.attention_train(p["attn"], hpre, cfg=cfg, ctx=ctx,
                                 positions=positions)
    x = x + y
    hpre = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
    y, aux = _mlp_or_moe(p, hpre, aux, cfg, ctx)
    return x + y, aux


def forward_hidden(params, embeds, *, cfg: ArchConfig, ctx: ShardCtx,
                   positions):
    """embeds: (B,S,d) -> (hidden, aux)."""
    x = ctx.hint(embeds, ctx.batch, None, None)
    aux = jnp.zeros((), jnp.float32)
    for (kind, count), stacked in zip(group_plan(cfg), params["groups"]):
        blk = jax.checkpoint(partial(_block_train, kind=kind, cfg=cfg,
                                     ctx=ctx, positions=positions))

        def body(carry, p, _blk=blk):
            x, a = carry
            x, a = _blk(p, x, a)
            return (x, a), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), stacked)
    x = apply_norm(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    return x, aux


def embed_tokens(params, tokens, ctx: ShardCtx):
    x = jnp.take(params["embed"]["emb"], tokens, axis=0)
    return ctx.hint(x, ctx.batch, None, None)


def head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["head"]["w"]


def make_positions(cfg: ArchConfig, b: int, s: int, offset=0):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)) + offset
    if cfg.rope_mode == "mrope":
        return text_mrope_positions(pos)
    return pos


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _mamba_sub_indices(cfg: ArchConfig) -> list[int]:
    return [i for i, s in enumerate(cfg.block_pattern) if s == "mamba"]


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    """Zeroed decode cache sized for `max_len` tokens."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    groups = []
    for kind, n in group_plan(cfg):
        if kind.startswith("std"):
            groups.append({
                "k": jnp.zeros((n, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((n, batch, max_len, kv, hd), dtype)})
        elif kind.startswith("mla"):
            m = cfg.mla
            groups.append({
                "ckv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                "kr": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim),
                                dtype)})
        elif kind == "rwkv":
            h = cfg.d_model // cfg.rwkv.head_size
            hs = cfg.rwkv.head_size
            groups.append({
                "S": jnp.zeros((n, batch, h, hs, hs), jnp.float32),
                "tm": jnp.zeros((n, batch, cfg.d_model), dtype),
                "cm": jnp.zeros((n, batch, cfg.d_model), dtype)})
        elif kind == "period":
            nm = len(_mamba_sub_indices(cfg))
            di = cfg.ssm.expand * cfg.d_model
            groups.append({
                "k": jnp.zeros((n, batch, max_len, kv, hd), dtype),
                "v": jnp.zeros((n, batch, max_len, kv, hd), dtype),
                "h": jnp.zeros((n, nm, batch, di, cfg.ssm.d_state),
                               jnp.float32),
                "conv": jnp.zeros((n, nm, batch, cfg.ssm.d_conv - 1, di),
                                  dtype)})
        else:
            raise ValueError(kind)
    return {"len": jnp.zeros((batch,), jnp.int32), "groups": groups}


def _block_prefill(p, x, *, kind: str, cfg: ArchConfig, ctx: ShardCtx,
                   positions, max_len: int):
    """Returns (x, cache_entry) matching init_cache leaf layout (minus n)."""
    nk, eps = cfg.norm, cfg.norm_eps
    s = x.shape[1]
    pad = max_len - s

    def padS(a):  # pad the sequence axis (axis=1 after batch) to max_len
        if pad == 0:
            return a
        cfgpad = [(0, 0)] * a.ndim
        cfgpad[1] = (0, pad)
        return jnp.pad(a, cfgpad)

    if kind == "rwkv":
        b, _, d = x.shape
        h, hs = cfg.d_model // cfg.rwkv.head_size, cfg.rwkv.head_size
        zshift = jnp.zeros((b, d), x.dtype)
        zstate = jnp.zeros((b, h, hs, hs), jnp.float32)
        y, tm_shift, S = rwkv.time_mix_train(
            p["tm"], apply_norm(p["ln1"], x, kind=nk, eps=eps),
            zshift, zstate, cfg=cfg, ctx=ctx)
        x = x + y
        y, cm_shift = rwkv.channel_mix(
            p["cm"], apply_norm(p["ln2"], x, kind=nk, eps=eps),
            zshift, cfg=cfg, ctx=ctx)
        return x + y, {"S": S, "tm": tm_shift.astype(x.dtype),
                       "cm": cm_shift.astype(x.dtype)}
    if kind == "period":
        b = x.shape[0]
        di = cfg.ssm.expand * cfg.d_model
        hs_list, conv_list, kv_entry = [], [], None
        for i, sub in enumerate(cfg.block_pattern):
            sp = p[f"sub{i}"]
            hpre = apply_norm(sp["norm"], x, kind=nk, eps=eps)
            if sub == "attn":
                y, (kc, vc) = attn.attention_train(
                    sp["attn"], hpre, cfg=cfg, ctx=ctx, positions=positions,
                    return_kv=True)
                kv_entry = (padS(kc), padS(vc))
            else:
                zconv = jnp.zeros((b, cfg.ssm.d_conv - 1, di), x.dtype)
                zh = jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)
                y, conv_s, h_s = mam.mamba_train(sp["mamba"], hpre, zconv, zh,
                                                 cfg=cfg, ctx=ctx)
                hs_list.append(h_s)
                conv_list.append(conv_s)
            x = x + y
            hpre = apply_norm(sp["mlp_norm"], x, kind=nk, eps=eps)
            y, _ = _mlp_or_moe(sp, hpre, jnp.zeros((), jnp.float32), cfg, ctx)
            x = x + y
        return x, {"k": kv_entry[0].astype(x.dtype),
                   "v": kv_entry[1].astype(x.dtype),
                   "h": jnp.stack(hs_list),
                   "conv": jnp.stack(conv_list).astype(x.dtype)}
    hpre = apply_norm(p["attn_norm"], x, kind=nk, eps=eps)
    if "mla" in p:
        y, (ckv, kr) = mla_mod.mla_train(p["mla"], hpre, cfg=cfg, ctx=ctx,
                                         positions=positions,
                                         return_cache=True)
        entry = {"ckv": padS(ckv).astype(x.dtype),
                 "kr": padS(kr).astype(x.dtype)}
    else:
        y, (kc, vc) = attn.attention_train(p["attn"], hpre, cfg=cfg, ctx=ctx,
                                           positions=positions,
                                           return_kv=True)
        entry = {"k": padS(kc).astype(x.dtype), "v": padS(vc).astype(x.dtype)}
    x = x + y
    hpre = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
    y, _ = _mlp_or_moe(p, hpre, jnp.zeros((), jnp.float32), cfg, ctx)
    return x + y, entry


def _block_decode(p, x, cache, *, kind: str, cfg: ArchConfig, ctx: ShardCtx,
                  cache_len):
    nk, eps = cfg.norm, cfg.norm_eps
    if kind == "rwkv":
        y, tm_shift, S = rwkv.time_mix_decode(
            p["tm"], apply_norm(p["ln1"], x, kind=nk, eps=eps),
            cache["tm"].astype(x.dtype), cache["S"], cfg=cfg, ctx=ctx)
        x = x + y
        y, cm_shift = rwkv.channel_mix(
            p["cm"], apply_norm(p["ln2"], x, kind=nk, eps=eps),
            cache["cm"].astype(x.dtype), cfg=cfg, ctx=ctx)
        return x + y, {"S": S, "tm": tm_shift.astype(x.dtype),
                       "cm": cm_shift.astype(x.dtype)}
    if kind == "period":
        midx = 0
        new_cache = dict(cache)
        hs_out, conv_out = [], []
        for i, sub in enumerate(cfg.block_pattern):
            sp = p[f"sub{i}"]
            hpre = apply_norm(sp["norm"], x, kind=nk, eps=eps)
            if sub == "attn":
                y, nk_c, nv_c = attn.attention_decode(
                    sp["attn"], hpre, cache["k"], cache["v"], cfg=cfg,
                    ctx=ctx, cache_len=cache_len)
                new_cache["k"], new_cache["v"] = nk_c, nv_c
            else:
                y, conv_s, h_s = mam.mamba_decode(
                    sp["mamba"], hpre, cache["conv"][midx].astype(x.dtype),
                    cache["h"][midx], cfg=cfg, ctx=ctx)
                hs_out.append(h_s)
                conv_out.append(conv_s.astype(x.dtype))
                midx += 1
            x = x + y
            hpre = apply_norm(sp["mlp_norm"], x, kind=nk, eps=eps)
            y, _ = _mlp_or_moe(sp, hpre, jnp.zeros((), jnp.float32), cfg, ctx)
            x = x + y
        new_cache["h"] = jnp.stack(hs_out)
        new_cache["conv"] = jnp.stack(conv_out)
        return x, new_cache
    hpre = apply_norm(p["attn_norm"], x, kind=nk, eps=eps)
    if "mla" in p:
        y, ckv, kr = mla_mod.mla_decode(
            p["mla"], hpre, cache["ckv"], cache["kr"], cfg=cfg, ctx=ctx,
            cache_len=cache_len)
        entry = {"ckv": ckv, "kr": kr}
    else:
        y, kc, vc = attn.attention_decode(
            p["attn"], hpre, cache["k"], cache["v"], cfg=cfg, ctx=ctx,
            cache_len=cache_len)
        entry = {"k": kc, "v": vc}
    x = x + y
    hpre = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
    y, _ = _mlp_or_moe(p, hpre, jnp.zeros((), jnp.float32), cfg, ctx)
    return x + y, entry


def lm_prefill(params, batch: dict, *, cfg: ArchConfig, ctx: ShardCtx,
               max_len: int = 0):
    """Run the full prompt, return (last-token logits, filled cache)."""
    if "embeds" in batch:
        x = ctx.hint(batch["embeds"], ctx.batch, None, None)
    else:
        x = embed_tokens(params, batch["tokens"], ctx)
    b, s = x.shape[0], x.shape[1]
    max_len = max_len or s
    positions = make_positions(cfg, b, s)
    groups_cache = []
    for (kind, count), stacked in zip(group_plan(cfg), params["groups"]):
        blk = partial(_block_prefill, kind=kind, cfg=cfg, ctx=ctx,
                      positions=positions, max_len=max_len)

        def body(x, p, _blk=blk):
            x, entry = _blk(p, x)
            return x, entry

        x, entries = jax.lax.scan(body, x, stacked)
        groups_cache.append(entries)
    x = apply_norm(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = (x[:, -1] @ head_weight(params, cfg).astype(x.dtype)
              ).astype(jnp.float32)
    cache = {"len": jnp.full((b,), s, jnp.int32), "groups": groups_cache}
    return logits, cache


def lm_decode(params, cache: dict, batch: dict, *, cfg: ArchConfig,
              ctx: ShardCtx):
    """One decode step. batch['tokens']: (B,1). Returns (logits, cache)."""
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = embed_tokens(params, batch["tokens"], ctx)
    cache_len = cache["len"]
    new_groups = []
    for (kind, count), stacked, gcache in zip(
            group_plan(cfg), params["groups"], cache["groups"]):
        blk = partial(_block_decode, kind=kind, cfg=cfg, ctx=ctx,
                      cache_len=cache_len)

        def body(x, xs, _blk=blk):
            p, c = xs
            x, entry = _blk(p, x, c)
            return x, entry

        x, entries = jax.lax.scan(body, x, (stacked, gcache))
        new_groups.append(entries)
    x = apply_norm(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = (x[:, -1] @ head_weight(params, cfg).astype(x.dtype)
              ).astype(jnp.float32)
    return logits, {"len": cache_len + 1, "groups": new_groups}
