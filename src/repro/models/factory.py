"""Model factory: one uniform API over every assigned architecture.

  init_params(key, cfg, dtype, max_seq)       -> params pytree
  train_loss(params, batch, cfg, ctx)         -> (loss, metrics)
  prefill(params, batch, cfg, ctx, max_len)   -> (logits, cache)
  decode(params, cache, batch, cfg, ctx)      -> (logits, cache)
  init_cache(cfg, batch, max_len, dtype)      -> zeroed cache pytree
  make_batch(key, cfg, shape, dtype)          -> concrete dummy batch
  batch_specs(cfg, shape, dtype)              -> ShapeDtypeStruct batch
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import lm, whisper
from repro.models.loss import chunked_cross_entropy
from repro.parallelism.ctx import NULL_CTX, ShardCtx

AUX_WEIGHT = 0.01


def init_params(key, cfg: ArchConfig, dtype=jnp.float32,
                max_seq: int = 4096) -> dict:
    if cfg.enc_dec:
        return whisper.init_whisper(key, cfg, dtype, max_dec_len=max_seq)
    return lm.init_lm(key, cfg, dtype)


def train_loss(params, batch: dict, *, cfg: ArchConfig,
               ctx: ShardCtx = NULL_CTX):
    if cfg.enc_dec:
        enc_out = whisper.encode(params, batch["frames"], cfg=cfg, ctx=ctx)
        hidden = whisper.decoder_train(params, batch["tokens"], enc_out,
                                       cfg=cfg, ctx=ctx)
        aux = jnp.zeros((), jnp.float32)
    else:
        if "embeds" in batch:
            x = ctx.hint(batch["embeds"], ctx.batch, None, None)
        else:
            x = lm.embed_tokens(params, batch["tokens"], ctx)
        b, s = x.shape[0], x.shape[1]
        positions = lm.make_positions(cfg, b, s)
        hidden, aux = lm.forward_hidden(params, x, cfg=cfg, ctx=ctx,
                                        positions=positions)
    w = (params["embed"]["emb"].T if cfg.tie_embeddings
         else params["head"]["w"])
    ce = chunked_cross_entropy(hidden, w, batch["labels"], ctx=ctx)
    loss = ce + AUX_WEIGHT * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill(params, batch: dict, *, cfg: ArchConfig,
            ctx: ShardCtx = NULL_CTX, max_len: int = 0):
    if cfg.enc_dec:
        return whisper.whisper_prefill(params, batch, cfg=cfg, ctx=ctx,
                                       max_len=max_len)
    return lm.lm_prefill(params, batch, cfg=cfg, ctx=ctx, max_len=max_len)


def decode(params, cache: dict, batch: dict, *, cfg: ArchConfig,
           ctx: ShardCtx = NULL_CTX):
    if cfg.enc_dec:
        return whisper.whisper_decode(params, cache, batch, cfg=cfg, ctx=ctx)
    return lm.lm_decode(params, cache, batch, cfg=cfg, ctx=ctx)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    if cfg.enc_dec:
        return whisper.init_whisper_cache(cfg, batch, max_len, dtype)
    return lm.init_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def _batch_shapes(cfg: ArchConfig, shape: ShapeSpec, dtype) -> dict:
    """name -> (shape, dtype) for the *training/prefill* batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.enc_dec:
        return {"frames": ((b, whisper.ENC_LEN, cfg.d_model), dtype),
                "tokens": ((b, s), jnp.int32),
                "labels": ((b, s), jnp.int32)}
    if cfg.frontend == "vision":
        return {"embeds": ((b, s, cfg.d_model), dtype),
                "labels": ((b, s), jnp.int32)}
    return {"tokens": ((b, s), jnp.int32),
            "labels": ((b, s), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    return {k: jax.ShapeDtypeStruct(sh, dt)
            for k, (sh, dt) in _batch_shapes(cfg, shape, dtype).items()}


def make_batch(key, cfg: ArchConfig, shape: ShapeSpec,
               dtype=jnp.float32) -> dict:
    out = {}
    for name, (sh, dt) in _batch_shapes(cfg, shape, dtype).items():
        key, sub = jax.random.split(key)
        if dt == jnp.int32:
            out[name] = jax.random.randint(sub, sh, 0, cfg.vocab_size,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(sub, sh, jnp.float32).astype(dt)
    return out


def decode_batch_specs(cfg: ArchConfig, shape: ShapeSpec,
                       dtype=jnp.bfloat16) -> dict:
    b = shape.global_batch
    if cfg.frontend == "vision":
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), dtype)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def make_decode_batch(key, cfg: ArchConfig, batch: int,
                      dtype=jnp.float32) -> dict:
    if cfg.frontend == "vision":
        return {"embeds": jax.random.normal(key, (batch, 1, cfg.d_model),
                                            jnp.float32).astype(dtype)}
    return {"tokens": jax.random.randint(key, (batch, 1), 0, cfg.vocab_size,
                                         dtype=jnp.int32)}


def generate(params, cfg, prompts, *, max_new: int = 16, ctx=NULL_CTX):
    """prompts: (B, S) int32. Greedy decode max_new tokens."""
    b, s = prompts.shape
    logits, cache = prefill(params, {"tokens": prompts}, cfg=cfg,
                            ctx=ctx, max_len=s + max_new)
    step = jax.jit(lambda p, c, t: decode(p, c, {"tokens": t},
                                          cfg=cfg, ctx=ctx))
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    for _ in range(max_new - 1):
        logits, cache = step(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    return jnp.concatenate(toks, axis=1)
