"""Shared layer primitives: norms, initializers, activations, dtype policy."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    accum_dtype: jnp.dtype = jnp.float32


F32 = Policy()
BF16 = Policy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms.  Params: {'scale': (d,)} (+ {'bias': (d,)} for layernorm).
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(params: dict, x, *, kind: str, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * params["scale"].astype(jnp.float32)
    if "bias" in params:
        x = x + params["bias"].astype(jnp.float32)
    return x.astype(dt)


def group_norm_heads(x, scale, bias, *, eps: float = 64e-5):
    """Per-head group norm (RWKV wkv output). x: (..., H, hs)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name == "swiglu":
        raise ValueError("swiglu is handled structurally in ffn.py")
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise ValueError(f"unknown activation {name}")


def sinusoidal_embedding(length: int, dim: int, dtype=jnp.float32):
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype)
