"""Rotary position embeddings — block-local pairing.

head_dim is viewed as (hd//8) blocks of 8; rotation partners are (i, i+4)
inside each block.  Partners therefore never cross an 8-aligned boundary, so
the head_dim axis can be tensor-sharded (used when n_heads % tp != 0:
phi3-medium 40H, arctic 56H, qwen2-vl 12H — see DESIGN.md) without strided
cross-shard slicing.  The pairing is a fixed reparameterization — models are
trained from scratch, so it is exactly as expressive as the HF layout.

Supports standard RoPE and Qwen2-VL M-RoPE (3 position streams split over
pair sections; (16,24,24) for hd=128).
"""
from __future__ import annotations

import jax.numpy as jnp

ROPE_BLOCK = 8
_HALF = ROPE_BLOCK // 2


def rope_frequencies(head_dim: int, theta: float):
    """Per-pair inverse frequencies, shape (head_dim//2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _apply_angles(x, angles):
    """x: (..., H, hd); angles: broadcastable to x's batch dims + (hd//2,)."""
    dt = x.dtype
    shape = x.shape
    hd = shape[-1]
    nb = hd // ROPE_BLOCK
    x = x.astype(jnp.float32).reshape(shape[:-1] + (nb, ROPE_BLOCK))
    x1 = x[..., :_HALF]
    x2 = x[..., _HALF:]
    ang = angles.reshape(angles.shape[:-1] + (nb, _HALF))
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.concatenate([r1, r2], axis=-1).reshape(shape)
    return out.astype(dt)


def apply_rope(x, positions, *, theta: float):
    """Standard RoPE.  x: (B, S, H, hd); positions: (B, S) int32."""
    inv = rope_frequencies(x.shape[-1], theta)                   # (hd/2,)
    ang = positions[..., None, None].astype(jnp.float32) * inv   # (B,S,1,hd/2)
    return _apply_angles(x, ang)


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Pair-section sizes (t, h, w): (16, 24, 24) for hd=128 (Qwen2-VL),
    generalized to 1/4, 3/8, 3/8 of the pair count."""
    pairs = head_dim // 2
    t = pairs // 4
    h = (pairs - t) // 2
    w = pairs - t - h
    return t, h, w


def apply_mrope(x, positions3, *, theta: float):
    """M-RoPE.  x: (B, S, H, hd); positions3: (3, B, S) int32 (t/h/w)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                            # (hd/2,)
    secs = mrope_sections(hd)
    sec_id = jnp.concatenate([
        jnp.full((secs[0],), 0), jnp.full((secs[1],), 1),
        jnp.full((secs[2],), 2)]).astype(jnp.int32)              # (hd/2,)
    pos = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)    # (B, S, 3)
    pos_per_pair = pos[..., sec_id]                              # (B, S, hd/2)
    ang = pos_per_pair[..., None, :] * inv                       # (B,S,1,hd/2)
    return _apply_angles(x, ang)


def text_mrope_positions(positions):
    """Text-only M-RoPE: all three streams equal.  (B,S) -> (3,B,S)."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
