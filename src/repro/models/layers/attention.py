"""GQA attention: init, chunked online-softmax training path, decode path.

Weight layout keeps heads 3-D — wq: (d, H, hd) — so tensor parallelism can
shard either the head axis (H % tp == 0) or the head_dim axis (hd % tp == 0,
with block-local RoPE pairing; see rope.py).  KV heads are repeated to H
before the score einsum (replicated KV params when KV % tp != 0).

Training/prefill uses a causal *block-pair scan*: only the (q_chunk,kv_chunk)
pairs inside the causal triangle are enumerated (static pair list), each pair
updating an online-softmax accumulator — flash-attention dataflow expressed
in pure JAX, so HLO FLOPs already exclude the masked upper triangle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers.rope import apply_mrope, apply_rope
from repro.parallelism.ctx import NULL_CTX, ShardCtx

NEG_INF = -1e30


def head_axes(ctx: ShardCtx, n_heads: int, head_dim: int):
    """(head_axis, head_dim_axis) PartitionSpec entries for (H, hd) dims."""
    if ctx.tp_axis is None or ctx.tp_size <= 1:
        return None, None
    if n_heads % ctx.tp_size == 0:
        return ctx.tp_axis, None
    if head_dim % ctx.tp_size == 0:
        return None, ctx.tp_axis
    return None, None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype, *, cross: bool = False) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (scale * jax.random.normal(ks[0], (d, h, hd))).astype(dtype),
        "wk": (scale * jax.random.normal(ks[1], (d, kv, hd))).astype(dtype),
        "wv": (scale * jax.random.normal(ks[2], (d, kv, hd))).astype(dtype),
        "wo": ((h * hd) ** -0.5
               * jax.random.normal(ks[3], (h, hd, d))).astype(dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _project_q(p, x, cfg: ArchConfig, ctx: ShardCtx):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    ha, ka = head_axes(ctx, cfg.n_heads, cfg.resolved_head_dim)
    return ctx.hint(q, ctx.batch, None, ha, ka)


def _project_kv(p, x, cfg: ArchConfig, ctx: ShardCtx):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return k, v


def repeat_kv(k, n_heads: int, ctx: ShardCtx, head_dim: int,
              hint: bool = True):
    """(B,S,KV,hd) -> (B,S,H,hd).  hint=False on the decode path: the cache
    is sequence-sharded and must NOT be resharded to the head layout."""
    kvh = k.shape[2]
    if kvh != n_heads:
        k = jnp.repeat(k, n_heads // kvh, axis=2)
    if not hint:
        return k
    ha, ka = head_axes(ctx, n_heads, head_dim)
    return ctx.hint(k, ctx.batch, None, ha, ka)


def _rope(q, positions, cfg: ArchConfig):
    if cfg.rope_mode == "rope":
        return apply_rope(q, positions, theta=cfg.rope_theta)
    if cfg.rope_mode == "mrope":
        return apply_mrope(q, positions, theta=cfg.rope_theta)
    return q  # 'none' / 'sinusoidal' (handled at the embedding)


# ---------------------------------------------------------------------------
# core attention maths
# ---------------------------------------------------------------------------

def direct_attention(q, k, v, *, causal: bool, kv_valid=None, ctx=NULL_CTX):
    """Materialized-score attention (small seq / decode).

    q: (B,Sq,H,hd); k,v: (B,Skv,H,hd); kv_valid: (B,Skv) bool or None.
    """
    hd = q.shape[-1]
    s = jnp.einsum("bqhk,bshk->bhqs", q, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        # query i sits at absolute position (skv - sq + i)
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    if kv_valid is not None:
        s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _causal_pairs(tq: int, tk: int, cq: int, ck: int):
    """Static (i, j) block-pair list covering the causal triangle, plus
    first/last flags per pair (row-major in i, ascending j)."""
    pairs = []
    for i in range(tq):
        q_hi = (i + 1) * cq - 1
        js = [j for j in range(tk) if j * ck <= q_hi]
        for n, j in enumerate(js):
            pairs.append((i, j, n == 0, n == len(js) - 1))
    arr = np.array(pairs, dtype=np.int32)
    return (jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
            jnp.asarray(arr[:, 2]), jnp.asarray(arr[:, 3]))


def chunked_attention(q, k, v, *, causal: bool = True,
                      chunk_q: int = 1024, chunk_k: int = 1024,
                      direct_threshold: int = 2048, ctx=NULL_CTX):
    """Online-softmax block attention.  q,k,v: (B,S,H,hd) (kv repeated)."""
    b, sq, h, hd = q.shape
    skv, dv = k.shape[1], v.shape[-1]
    if sq <= direct_threshold and skv <= direct_threshold:
        return direct_attention(q, k, v, causal=causal, ctx=ctx)
    if skv <= direct_threshold and not causal:
        # long queries over a short KV (e.g. cross-attention): chunk q only
        cq = min(chunk_q, sq)
        assert sq % cq == 0, (sq, cq)

        def qblock(carry, i):
            qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
            oi = direct_attention(qi, k, v, causal=False, ctx=ctx)
            return carry, oi

        _, blocks = jax.lax.scan(qblock, 0, jnp.arange(sq // cq))
        return jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, dv)

    cq, ck = min(chunk_q, sq), min(chunk_k, skv)
    assert sq % cq == 0 and skv % ck == 0, (sq, cq, skv, ck)
    tq, tk = sq // cq, skv // ck
    if causal:
        ii, jj, first, last = _causal_pairs(tq, tk, cq, ck)
    else:
        grid = np.mgrid[0:tq, 0:tk].reshape(2, -1)
        ii = jnp.asarray(grid[0].astype(np.int32))
        jj = jnp.asarray(grid[1].astype(np.int32))
        first = jnp.asarray(grid[1] == 0)
        last = jnp.asarray(grid[1] == tk - 1)

    scale = hd ** -0.5
    offset = skv - sq  # absolute position offset of q within kv (causal)

    def body(carry, xs):
        m, l, acc, out = carry
        i, j, fst, lst = xs
        qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        s = jnp.einsum("bqhk,bshk->bhqs", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * cq + jnp.arange(cq)[:, None] + offset
            kpos = j * ck + jnp.arange(ck)[None, :]
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m0 = jnp.where(fst, NEG_INF, m)
        l0 = jnp.where(fst, 0.0, l)
        acc0 = jnp.where(fst, 0.0, acc)
        m_new = jnp.maximum(m0, s.max(axis=-1))            # (B,H,Cq)
        corr = jnp.exp(m0 - m_new)
        p = jnp.exp(s - m_new[..., None])                  # (B,H,Cq,Ck)
        l_new = l0 * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqs,bshk->bhqk", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc0 * corr[..., None] + pv
        o_block = (acc_new / jnp.maximum(l_new[..., None], 1e-30))
        o_block = jnp.transpose(o_block, (0, 2, 1, 3)).astype(q.dtype)
        out = jax.lax.cond(
            lst,
            lambda o: jax.lax.dynamic_update_slice_in_dim(o, o_block, i * cq,
                                                          axis=1),
            lambda o: o, out)
        return (m_new, l_new, acc_new, out), None

    m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, cq), jnp.float32)
    acc0 = jnp.zeros((b, h, cq, dv), jnp.float32)
    out0 = jnp.zeros(q.shape[:-1] + (dv,), q.dtype)
    (_, _, _, out), _ = jax.lax.scan(body, (m0, l0, acc0, out0),
                                     (ii, jj, first, last))
    return out


# ---------------------------------------------------------------------------
# layer-level entry points
# ---------------------------------------------------------------------------

def attention_train(p, x, *, cfg: ArchConfig, ctx: ShardCtx, positions,
                    causal: bool = True, chunk: int = 1024,
                    return_kv: bool = False):
    """Full-sequence attention (training / prefill)."""
    hd = cfg.resolved_head_dim
    q = _project_q(p, x, cfg, ctx)
    k, v = _project_kv(p, x, cfg, ctx)
    q = _rope(q, positions, cfg)
    k = _rope(k, positions, cfg)
    kf = repeat_kv(k, cfg.n_heads, ctx, hd, hint=False)
    vf = repeat_kv(v, cfg.n_heads, ctx, hd, hint=False)
    h_ax, hd_ax = head_axes(ctx, cfg.n_heads, hd)
    sq = q.shape[1]
    if (hd_ax is not None and h_ax is None and sq % ctx.tp_size == 0
            and (sq // ctx.tp_size) >= 128 and sq == kf.shape[1]):
        # head_dim-sharded arch on a long sequence: sequence-block-parallel
        # attention (see seqpar_attention docstring)
        o = seqpar_attention(q, kf, vf, causal=causal, ctx=ctx)
    else:
        kf = repeat_kv(kf, cfg.n_heads, ctx, hd)   # apply layout hint
        vf = repeat_kv(vf, cfg.n_heads, ctx, hd)
        o = chunked_attention(q, kf, vf, causal=causal, chunk_q=chunk,
                              chunk_k=chunk, ctx=ctx)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)   # roped, pre-repeat: the KV-cache entries
    return out


def cross_attention_train(p, x, enc, *, cfg: ArchConfig, ctx: ShardCtx):
    """Encoder-decoder cross attention (whisper). enc: (B,Senc,d)."""
    hd = cfg.resolved_head_dim
    q = _project_q(p, x, cfg, ctx)
    k, v = _project_kv(p, enc, cfg, ctx)
    k = repeat_kv(k, cfg.n_heads, ctx, hd)
    v = repeat_kv(v, cfg.n_heads, ctx, hd)
    o = chunked_attention(q, k, v, causal=False, ctx=ctx)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def seqpar_attention(q, k, v, *, causal: bool, ctx: ShardCtx,
                     chunk_k: int = 512):
    """Sequence-block-parallel attention for head_dim-sharded architectures
    (n_heads % tp != 0 — phi3 40H, arctic 56H, qwen2-vl 12H).

    Head-dim TP would all-reduce every (Sq×Sk) score block across the model
    axis (the QK^T einsum contracts the sharded hd axis) — for a 32k prefill
    that is TBs of ICI traffic per device.  Instead: queries are resharded
    into tp sequence blocks (cheap all-to-all), K/V are gathered once per
    layer, and each device runs an online-softmax scan over KV chunks for
    its own query slab.  Collectives drop from O(S²·H) to O(S·H·hd).
    """
    b, s, h, hd = q.shape
    dv = v.shape[-1]
    g = ctx.tp_size
    sg = s // g
    ck = min(chunk_k, s)
    nk = s // ck
    scale = hd ** -0.5
    # single up-front transpose to a loop-stable (b,g,h,sg,·) layout —
    # every in-loop tensor (scores, probs, acc, m, l) shares it, so XLA
    # inserts no per-chunk layout copies.
    qb = jnp.moveaxis(q.reshape(b, g, sg, h, hd), 3, 2)   # (b,g,h,sg,hd)
    qb = ctx.hint(qb, ctx.batch, ctx.tp_axis, None, None, None)
    k = ctx.hint(k, ctx.batch, None, None, None)      # gather K over model
    v = ctx.hint(v, ctx.batch, None, None, None)
    kh = jnp.moveaxis(k, 2, 1)                        # (b,h,s,hd)
    vh = jnp.moveaxis(v, 2, 1)

    def body(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(kh, j * ck, ck, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(vh, j * ck, ck, axis=2)
        sc = jnp.einsum("bghqk,bhsk->bghqs", qb, kj,
                        preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = (jnp.arange(g)[:, None] * sg
                    + jnp.arange(sg)[None, :])            # (g, sg)
            kpos = j * ck + jnp.arange(ck)
            mask = qpos[..., None] >= kpos[None, None, :]  # (g, sg, ck)
            sc = jnp.where(mask[None, :, None, :, :], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p32 = jnp.exp(sc - m_new[..., None])
        l_new = l * corr + p32.sum(axis=-1)
        pv = jnp.einsum("bghqs,bhsk->bghqk", p32.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    # the carry inits must carry the g-sharding too — GSPMD derives the
    # loop-invariant sharding from them (unhinted zeros ⇒ the whole scan
    # would run replicated over the model axis, 16× redundant)
    m0 = ctx.hint(jnp.full((b, g, h, sg), NEG_INF, jnp.float32),
                  ctx.batch, ctx.tp_axis, None, None)
    l0 = ctx.hint(jnp.zeros((b, g, h, sg), jnp.float32),
                  ctx.batch, ctx.tp_axis, None, None)
    acc0 = ctx.hint(jnp.zeros((b, g, h, sg, dv), jnp.float32),
                    ctx.batch, ctx.tp_axis, None, None, None)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nk))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = jnp.moveaxis(o, 2, 3).reshape(b, s, h, dv).astype(q.dtype)
    # back to the head_dim-sharded layout for the row-parallel out-proj
    ha, ka = head_axes(ctx, h, hd)
    return ctx.hint(o, ctx.batch, None, ha, ka)


def gqa_decode_attention(q, k_cache, v_cache, kv_valid):
    """Grouped decode attention WITHOUT materializing the KV repeat.

    q: (B,1,H,hd); k_cache/v_cache: (B,S,KV,hd) (sequence-sharded);
    kv_valid: (B,S) bool.  Each device streams its cache shard exactly once;
    softmax statistics reduce over the sharded S axis (GSPMD → all-reduce).
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = jnp.where(kv_valid[:, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskh->bqkgh", (p / l).astype(v_cache.dtype),
                   v_cache, preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, max_len: int,
                  dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kv, hd), dtype),
    }


def attention_decode(p, x, cache_k, cache_v, *, cfg: ArchConfig,
                     ctx: ShardCtx, cache_len):
    """One-token decode. x: (B,1,d); cache_k/v: (B,Smax,KV,hd);
    cache_len: (B,) int32 current lengths.  Returns (out, new_k, new_v)."""
    hd = cfg.resolved_head_dim
    b, smax = cache_k.shape[0], cache_k.shape[1]
    positions = cache_len[:, None]  # (B,1)
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(positions[None], (3, b, 1))
    else:
        pos = positions
    q = _project_q(p, x, cfg, ctx)
    k_new, v_new = _project_kv(p, x, cfg, ctx)
    q = _rope(q, pos, cfg)
    k_new = _rope(k_new, pos, cfg)
    # scatter the new token into the cache at (b, cache_len[b])
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, cache_len].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, cache_len].set(v_new[:, 0].astype(cache_v.dtype))
    kv_valid = jnp.arange(smax)[None, :] <= cache_len[:, None]
    o = gqa_decode_attention(q, cache_k.astype(x.dtype),
                             cache_v.astype(x.dtype), kv_valid)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


def cross_attention_decode(p, x, cross_k, cross_v, *, cfg: ArchConfig,
                           ctx: ShardCtx):
    """Decode-time cross attention against precomputed encoder K/V."""
    hd = cfg.resolved_head_dim
    q = _project_q(p, x, cfg, ctx)
    k_full = repeat_kv(cross_k.astype(x.dtype), cfg.n_heads, ctx, hd)
    v_full = repeat_kv(cross_v.astype(x.dtype), cfg.n_heads, ctx, hd)
    o = direct_attention(q, k_full, v_full, causal=False, ctx=ctx)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
