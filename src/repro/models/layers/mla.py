"""Multi-head Latent Attention (DeepSeek-V3).

Training path materializes per-head K/V from the KV latent; the decode path
uses the *absorbed* formulation — scores are taken directly against the
cached latent (c_kv, k_rope), so the KV cache holds only
(kv_lora_rank + qk_rope_head_dim) floats per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers.attention import chunked_attention, NEG_INF
from repro.models.layers.common import apply_norm, init_norm
from repro.models.layers.rope import apply_rope
from repro.parallelism.ctx import NULL_CTX, ShardCtx


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wdq": (s * jax.random.normal(ks[0], (d, m.q_lora_rank))).astype(dtype),
        "q_norm": init_norm("rmsnorm", m.q_lora_rank, dtype),
        "wuq": (m.q_lora_rank ** -0.5 * jax.random.normal(
            ks[1], (m.q_lora_rank, h, qk + m.qk_rope_head_dim))).astype(dtype),
        "wdkv": (s * jax.random.normal(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim))).astype(dtype),
        "kv_norm": init_norm("rmsnorm", m.kv_lora_rank, dtype),
        "wuk": (m.kv_lora_rank ** -0.5 * jax.random.normal(
            ks[3], (m.kv_lora_rank, h, qk))).astype(dtype),
        "wuv": (m.kv_lora_rank ** -0.5 * jax.random.normal(
            ks[4], (m.kv_lora_rank, h, m.v_head_dim))).astype(dtype),
        "wo": ((h * m.v_head_dim) ** -0.5 * jax.random.normal(
            ks[5], (h, m.v_head_dim, d))).astype(dtype),
    }


def _queries(p, x, cfg: ArchConfig, ctx: ShardCtx, positions):
    m = cfg.mla
    cq = x @ p["wdq"].astype(x.dtype)
    cq = apply_norm(p["q_norm"], cq, kind="rmsnorm", eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(x.dtype))
    q = ctx.hint(q, ctx.batch, None, ctx.tp_if(cfg.n_heads), None)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        theta=cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg: ArchConfig, positions):
    m = cfg.mla
    ckr = x @ p["wdkv"].astype(x.dtype)            # (B,S,dc+rope)
    ckv = apply_norm(p["kv_norm"], ckr[..., :m.kv_lora_rank],
                     kind="rmsnorm", eps=cfg.norm_eps)
    k_rope = apply_rope(ckr[..., None, m.kv_lora_rank:], positions,
                        theta=cfg.rope_theta)[..., 0, :]   # (B,S,rope)
    return ckv, k_rope


def mla_train(p, x, *, cfg: ArchConfig, ctx: ShardCtx, positions,
              chunk: int = 1024, return_cache: bool = False):
    m = cfg.mla
    q_nope, q_rope = _queries(p, x, cfg, ctx, positions)
    ckv, k_rope = _latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"].astype(x.dtype))
    k_nope = ctx.hint(k_nope, ctx.batch, None, ctx.tp_if(cfg.n_heads), None)
    v = ctx.hint(v, ctx.batch, None, ctx.tp_if(cfg.n_heads), None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_head_dim,))],
        axis=-1)
    o = chunked_attention(q, k, v, causal=True, chunk_q=chunk, chunk_k=chunk,
                          ctx=ctx)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if return_cache:
        return out, (ckv, k_rope)
    return out


def mla_decode(p, x, cache_ckv, cache_krope, *, cfg: ArchConfig,
               ctx: ShardCtx, cache_len):
    """Absorbed decode.  x: (B,1,d); cache_ckv: (B,Smax,dc);
    cache_krope: (B,Smax,rope)."""
    m = cfg.mla
    b, smax = cache_ckv.shape[0], cache_ckv.shape[1]
    positions = cache_len[:, None]
    q_nope, q_rope = _queries(p, x, cfg, ctx, positions)
    ckv_new, krope_new = _latents(p, x, cfg, positions)
    bidx = jnp.arange(b)
    cache_ckv = cache_ckv.at[bidx, cache_len].set(
        ckv_new[:, 0].astype(cache_ckv.dtype))
    cache_krope = cache_krope.at[bidx, cache_len].set(
        krope_new[:, 0].astype(cache_krope.dtype))
    # absorb W_uk into q:  q_c = q_nope @ W_uk^T  -> (B,1,H,dc)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(x.dtype))
    s = jnp.einsum("bshr,btr->bhst", q_c, cache_ckv.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshk,btk->bhst", q_rope,
                       cache_krope.astype(x.dtype),
                       preferred_element_type=jnp.float32)
    s = s * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    valid = jnp.arange(smax)[None, :] <= cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhst,btr->bshr", prob.astype(x.dtype),
                     cache_ckv.astype(x.dtype))      # (B,1,H,dc)
    o = jnp.einsum("bshr,rhk->bshk", o_c, p["wuv"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache_ckv, cache_krope
