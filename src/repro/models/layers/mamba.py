"""Mamba-1 selective SSM (jamba's mamba sublayer).

The diagonal recurrence  h_t = exp(Δ_t·A)⊙h_{t-1} + Δ_t·B_t·u_t  is affine,
so it is evaluated with an intra-chunk ``lax.associative_scan`` plus an
inter-chunk carry scan — the parallelized-serial-loop pattern again
(bit-identical to the step-by-step recurrence; asserted in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallelism.ctx import NULL_CTX, ShardCtx


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    ks = jax.random.split(key, 7)
    sc = d ** -0.5
    return {
        "wx": (sc * jax.random.normal(ks[0], (d, di))).astype(dtype),
        "wz": (sc * jax.random.normal(ks[1], (d, di))).astype(dtype),
        "conv_w": (0.1 * jax.random.normal(ks[2], (s.d_conv, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wxp": (di ** -0.5 * jax.random.normal(
            ks[3], (di, s.dt_rank + 2 * s.d_state))).astype(dtype),
        "wdt": (s.dt_rank ** -0.5 * jax.random.normal(
            ks[4], (s.dt_rank, di))).astype(dtype),
        "dt_bias": jnp.full((di,), -4.0, dtype),   # softplus ≈ 0.018
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, s.d_state))
        ).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "wo": (di ** -0.5 * jax.random.normal(ks[5], (di, d))).astype(dtype),
    }


def _conv_shift(u, conv_w, conv_b, init_state):
    """Causal depthwise conv via K shifted adds.
    u: (B,S,di); conv_w: (K,di); init_state: (B,K-1,di)."""
    k = conv_w.shape[0]
    padded = jnp.concatenate([init_state.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    s = u.shape[1]
    for i in range(k):
        out = out + padded[:, i:i + s] * conv_w[i].astype(u.dtype)
    return out + conv_b.astype(u.dtype), padded[:, -( k - 1):] if k > 1 else init_state


def _ssm_params(p, uc, cfg: ArchConfig):
    s = cfg.ssm
    xdbc = uc @ p["wxp"].astype(uc.dtype)
    dt_in = xdbc[..., :s.dt_rank]
    bmat = xdbc[..., s.dt_rank:s.dt_rank + s.d_state].astype(jnp.float32)
    cmat = xdbc[..., s.dt_rank + s.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_in @ p["wdt"].astype(uc.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                  # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di,ds)
    return dt, a, bmat, cmat


def ssm_chunked(dt, a, bmat, cmat, u, h0, *, chunk: int = 64):
    """Chunked diagonal SSM scan.
    dt: (B,S,di) fp32; a: (di,ds); bmat,cmat: (B,S,ds); u: (B,S,di);
    h0: (B,di,ds) fp32.  Returns (y (B,S,di) fp32, h_end)."""
    b, s, di = dt.shape
    ds = a.shape[-1]
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c

    def per_chunk(h, xs):
        dtc, bc, cc, uc = xs                         # (B,C,di) / (B,C,ds)
        da = jnp.exp(dtc[..., None] * a)             # (B,C,di,ds) ≤ 1
        dbu = (dtc * uc.astype(jnp.float32))[..., None] * bc[:, :, None, :]
        # affine scan: (a2,b2)∘(a1,b1) = (a2*a1, a2*b1 + b2)
        acc_a, acc_b = jax.lax.associative_scan(
            lambda p1, p2: (p2[0] * p1[0], p2[0] * p1[1] + p2[1]),
            (da, dbu), axis=1)
        h_t = acc_a * h[:, None] + acc_b             # (B,C,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", h_t, cc)
        return h_t[:, -1], y

    xs = tuple(jnp.moveaxis(x.reshape(b, nc, c, *x.shape[2:]), 1, 0)
               for x in (dt, bmat, cmat, u))
    h_end, y = jax.lax.scan(per_chunk, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, di)
    return y, h_end


def mamba_train(p, x, conv_state, h0, *, cfg: ArchConfig,
                ctx: ShardCtx = NULL_CTX, chunk: int = 64):
    """x: (B,S,d); conv_state: (B,K-1,di); h0: (B,di,ds) fp32.
    Returns (out, new_conv_state, h_end)."""
    di = cfg.ssm.expand * cfg.d_model
    u = x @ p["wx"].astype(x.dtype)
    z = x @ p["wz"].astype(x.dtype)
    u = ctx.hint(u, ctx.batch, None, ctx.tp_if(di))
    uc, new_conv = _conv_shift(u, p["conv_w"], p["conv_b"], conv_state)
    uc = jax.nn.silu(uc)
    dt, a, bmat, cmat = _ssm_params(p, uc, cfg)
    y, h_end = ssm_chunked(dt, a, bmat, cmat, uc, h0, chunk=chunk)
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * uc
    y = y * jax.nn.silu(z)
    return y @ p["wo"].astype(x.dtype), new_conv, h_end


def mamba_decode(p, x, conv_state, h, *, cfg: ArchConfig,
                 ctx: ShardCtx = NULL_CTX):
    """Single-step decode. x: (B,1,d)."""
    return mamba_train(p, x, conv_state, h, cfg=cfg, ctx=ctx, chunk=1)
