"""RWKV-6 ("Finch") — data-dependent-decay linear attention.

The wkv recurrence  S_t = diag(w_t)·S_{t-1} + k_t ⊗ v_t,
                    o_t = r_t·(S_{t-1} + diag(u)·k_t ⊗ v_t)
is evaluated in *chunked parallel form*: intra-chunk pairwise decays
(all exponents ≤ 0 ⇒ numerically safe) + an inter-chunk state scan.
This mirrors the paper's move — the dominant serial loop is parallelized
with bit-identical results (tests assert chunked ≡ step-by-step).

``wkv_chunked`` is the pure-jnp oracle; kernels/wkv6 provides the Pallas
version validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers.common import apply_norm, group_norm_heads, init_norm
from repro.parallelism.ctx import NULL_CTX, ShardCtx

N_MIX = 5  # w, k, v, r, g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_time_mix(key, cfg: ArchConfig, dtype) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": jnp.full((N_MIX, d), 0.5, dtype),
        "mix_w1": (s * jax.random.normal(ks[0], (d, N_MIX * r.mix_lora_rank))
                   ).astype(dtype),
        "mix_w2": (r.mix_lora_rank ** -0.5 * jax.random.normal(
            ks[1], (N_MIX, r.mix_lora_rank, d))).astype(dtype),
        "w0": (jnp.linspace(-6.0, -0.5, d)).astype(dtype),
        "wd1": (s * jax.random.normal(ks[2], (d, r.decay_lora_rank))
                ).astype(dtype),
        "wd2": (r.decay_lora_rank ** -0.5 * jax.random.normal(
            ks[3], (r.decay_lora_rank, d))).astype(dtype),
        "u": (0.1 * jax.random.normal(ks[4], (d,))).astype(dtype),
        "wr": (s * jax.random.normal(ks[5], (d, d))).astype(dtype),
        "wk": (s * jax.random.normal(ks[6], (d, d))).astype(dtype),
        "wv": (s * jax.random.normal(ks[7], (d, d))).astype(dtype),
        "wg": (s * jax.random.normal(ks[8], (d, d))).astype(dtype),
        "wo": (s * jax.random.normal(ks[9], (d, d))).astype(dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
    }


def init_channel_mix(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": (d ** -0.5 * jax.random.normal(ks[0], (d, f))).astype(dtype),
        "wv": (f ** -0.5 * jax.random.normal(ks[1], (f, d))).astype(dtype),
        "wr": (d ** -0.5 * jax.random.normal(ks[2], (d, d))).astype(dtype),
    }


# ---------------------------------------------------------------------------
# wkv core — chunked parallel oracle
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, wlog, u, state, *, chunk: int = 64):
    """r,k,v,wlog: (B,S,H,hs) (wlog = log decay ≤ 0, fp32);
    u: (H,hs); state: (B,H,hs,hs) fp32.  Returns (o, new_state)."""
    b, s, h, hs = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    rc = r.reshape(b, nc, c, h, hs).astype(jnp.float32)
    kc = k.reshape(b, nc, c, h, hs).astype(jnp.float32)
    vc = v.reshape(b, nc, c, h, hs).astype(jnp.float32)
    wc = wlog.reshape(b, nc, c, h, hs).astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def per_chunk(S, xs):
        rr, kk, vv, ww = xs                      # (B,C,H,hs)
        L = jnp.cumsum(ww, axis=1)               # inclusive logs, ≤0, decreasing
        Lprev = L - ww
        Lend = L[:, -1:]                         # (B,1,H,hs)
        # inter-chunk: o_t += (r_t ⊙ exp(Lprev_t)) @ S
        o_inter = jnp.einsum("bthi,bhij->bthj", rr * jnp.exp(Lprev), S)
        # intra-chunk pairwise decays (t>s): exp(Lprev_t - L_s) ≤ 1
        Dexp = jnp.exp(Lprev[:, :, None] - L[:, None, :])   # (B,C,C,H,hs)
        mask = jnp.tril(jnp.ones((c, c), bool), -1)[None, :, :, None, None]
        Dexp = jnp.where(mask, Dexp, 0.0)
        scores = jnp.einsum("bthi,bshi,btshi->bhts", rr, kk, Dexp)
        o_intra = jnp.einsum("bhts,bshj->bthj", scores, vv)
        # bonus diagonal
        du = jnp.einsum("bthi,bthi->bth", rr, uf * kk)
        o_diag = du[..., None] * vv
        # state update: S' = exp(Lend)⊙S + Σ_s exp(Lend - L_s)⊙k_s ⊗ v_s
        kdec = kk * jnp.exp(Lend - L)
        S_new = jnp.exp(Lend)[:, 0, :, :, None] * S + \
            jnp.einsum("bshi,bshj->bhij", kdec, vv)
        return S_new, o_inter + o_intra + o_diag

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc))
    state, o = jax.lax.scan(per_chunk, state.astype(jnp.float32), xs)
    o = jnp.moveaxis(o, 0, 1).reshape(b, s, h, hs)
    return o, state


def wkv_step(r, k, v, wlog, u, state):
    """Single decode step. r,k,v,wlog: (B,H,hs); state: (B,H,hs,hs) fp32."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    uf = u.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]          # (B,H,hs,hs)
    o = jnp.einsum("bhi,bhij->bhj", rf, state + uf[..., None] * kv)
    state = jnp.exp(wlog.astype(jnp.float32))[..., None] * state + kv
    return o, state


# ---------------------------------------------------------------------------
# layer entry points
# ---------------------------------------------------------------------------

def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mixes.  Returns (xw,xk,xv,xr,xg)."""
    dx = x_prev - x
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    mr = p["mix_w2"].shape[1]
    lora = jnp.tanh(xxx @ p["mix_w1"].astype(x.dtype))
    lora = lora.reshape(lora.shape[:-1] + (N_MIX, mr))
    mix = p["mu"].astype(x.dtype) + jnp.einsum(
        "bsnr,nrd->bsnd", lora, p["mix_w2"].astype(x.dtype))
    return tuple(x + dx * mix[..., i, :] for i in range(N_MIX))


def _decay_log(p, xw):
    w_raw = p["w0"].astype(jnp.float32) + \
        jnp.tanh(xw @ p["wd1"].astype(xw.dtype)).astype(jnp.float32) @ \
        p["wd2"].astype(jnp.float32)
    return -jnp.exp(w_raw)          # log decay ≤ 0


def time_mix_train(p, x, shift_state, wkv_state, *, cfg: ArchConfig,
                   ctx: ShardCtx = NULL_CTX, chunk: int = 64,
                   use_kernel: bool = False):
    """x: (B,S,d). Returns (out, new_shift, new_wkv_state).
    use_kernel routes the wkv recurrence through the Pallas kernel
    (kernels/wkv6; train path only — initial state is zero)."""
    hs = cfg.rwkv.head_size
    b, s, d = x.shape
    h = d // hs
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    wlog = _decay_log(p, xw).reshape(b, s, h, hs)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(b, s, h, hs)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(b, s, h, hs)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(b, s, h, hs)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    r = ctx.hint(r, ctx.batch, None, ctx.tp_if(h), None)
    k = ctx.hint(k, ctx.batch, None, ctx.tp_if(h), None)
    v = ctx.hint(v, ctx.batch, None, ctx.tp_if(h), None)
    u = p["u"].astype(jnp.float32).reshape(h, hs)
    if use_kernel and s > 1:
        # Pallas kernel path (zero initial state = sequence start)
        from repro.kernels.wkv6.ops import wkv6_op
        o, wkv_state = wkv6_op(r, k, v, wlog, u, chunk=chunk)
    else:
        o, wkv_state = wkv_chunked(r, k, v, wlog, u, wkv_state, chunk=chunk)
    o = group_norm_heads(o.astype(x.dtype),
                         p["gn_scale"].reshape(h, hs),
                         p["gn_bias"].reshape(h, hs))
    o = o.reshape(b, s, d) * g
    return o @ p["wo"].astype(x.dtype), x[:, -1], wkv_state


def time_mix_decode(p, x, shift_state, wkv_state, *, cfg: ArchConfig,
                    ctx: ShardCtx = NULL_CTX):
    """x: (B,1,d)."""
    out, new_shift, wkv_state = time_mix_train(
        p, x, shift_state, wkv_state, cfg=cfg, ctx=ctx, chunk=1)
    return out, new_shift, wkv_state


def channel_mix(p, x, shift_state, *, cfg: ArchConfig,
                ctx: ShardCtx = NULL_CTX):
    """x: (B,S,d). Returns (out, new_shift)."""
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kk = ctx.hint(kk, ctx.batch, None, ctx.tp_if(kk.shape[-1]))
    kv = kk @ p["wv"].astype(x.dtype)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * kv
    return out, x[:, -1]
