"""Mixture-of-Experts with sort-based capacity dispatch.

Tokens are split into groups (one per data shard), sorted by expert id
inside each group (stable ⇒ deterministic), packed into a fixed-capacity
(G, E, C, d) buffer, then resharded so experts own their slots:

  placement modes (picked by ShardCtx.ep_axes, see parallelism/ctx.py):
    'full' — experts sharded over (data×model) combined  (deepseek 256e)
    '2d'   — experts over data, expert-FFN width over model (arctic 128e)
    'tp'   — experts over model only                        (jamba 16e)

GSPMD turns the layout change into the all-to-all; the un-dispatch is the
reverse.  Dropped tokens (over capacity) fall into a dead slot.  The router
runs in fp32; an auxiliary load-balance loss is returned to the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers.ffn import apply_ffn, init_ffn
from repro.parallelism.ctx import NULL_CTX, ShardCtx


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 6)
    si, so = d ** -0.5, f ** -0.5
    p = {
        "router": (si * jax.random.normal(ks[0], (d, e))).astype(jnp.float32),
        "wi_gate": (si * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
        "wi_up": (si * jax.random.normal(ks[2], (e, d, f))).astype(dtype),
        "wo": (so * jax.random.normal(ks[3], (e, f, d))).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_ffn(ks[4], d, m.n_shared_experts * f, cfg.act, dtype)
    if m.dense_residual:
        p["dense"] = init_ffn(ks[5], d, cfg.d_ff, cfg.act, dtype)
    return p


def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(n_tokens * top_k * cf / n_experts) + 1
    c = max(top_k, min(c, n_tokens * top_k))
    return -(-c // 4) * 4  # round up to a multiple of 4


def _dispatch_one_group(xg, top_idx, n_experts: int, capacity: int):
    """xg: (Ng,d); top_idx: (Ng,K). Returns (buf (E,C,d), slot, keep, order)."""
    ng, k = top_idx.shape
    flat_e = top_idx.reshape(-1)                       # (Ng*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    pos = jnp.arange(ng * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)
    buf = jnp.zeros((n_experts * capacity + 1, xg.shape[-1]), xg.dtype)
    buf = buf.at[slot].set(xg[order // k])
    return buf[:-1].reshape(n_experts, capacity, -1), slot, keep, order


def _combine_one_group(out_buf, slot, keep, order, weights, ng: int, k: int):
    """out_buf: (E,C,d) -> y (Ng,d)."""
    d = out_buf.shape[-1]
    flat = jnp.concatenate([out_buf.reshape(-1, d),
                            jnp.zeros((1, d), out_buf.dtype)], axis=0)
    vals = flat[slot] * (weights[order] * keep)[:, None].astype(out_buf.dtype)
    y = jnp.zeros((ng, d), out_buf.dtype).at[order // k].add(vals)
    return y


def apply_moe(p: dict, x, *, cfg: ArchConfig, ctx: ShardCtx = NULL_CTX):
    """x: (B,S,d). Returns (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    n = b * s
    g = ctx.dp_size if (ctx.dp_size > 1 and n % ctx.dp_size == 0
                        and n >= ctx.dp_size * k) else 1
    ng = n // g
    cap = _capacity(ng, k, e, m.capacity_factor)

    tokens = x.reshape(g, ng, d)
    tokens = ctx.hint(tokens, ctx.batch, None, None)

    # ---- router (fp32) -----------------------------------------------------
    logits = jnp.einsum("gnd,de->gne", tokens.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)           # (G,Ng,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance aux loss (scatter-add, no (N,E) one-hot)
    counts = jnp.zeros((e,), jnp.float32).at[top_idx[..., 0].reshape(-1)].add(1.0)
    frac = counts / (g * ng)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    # ---- dispatch -----------------------------------------------------------
    buf, slot, keep, order = jax.vmap(
        lambda xg, ti: _dispatch_one_group(xg, ti, e, cap))(tokens, top_idx)
    # buf: (G,E,C,d)

    ep_axis, ff_axis = ctx.ep_axes(e, m.d_ff_expert)
    # the group axis keeps its data sharding UNLESS the expert axis needs
    # those mesh axes (2-D / full EP) — replicating g when experts only use
    # the model axis would make every device compute every group (16-32×).
    ep_set = set(ep_axis if isinstance(ep_axis, tuple) else (ep_axis,)) \
        if ep_axis else set()
    g_spec = None if (ep_set & set(ctx.batch_axes)) else ctx.batch
    buf = ctx.hint(buf, g_spec, ep_axis, None, None)    # the all-to-all

    compute = buf
    gate = jnp.einsum("gecd,edf->gecf", compute, p["wi_gate"].astype(x.dtype))
    up = jnp.einsum("gecd,edf->gecf", compute, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    h = ctx.hint(h, g_spec, ep_axis, None, ff_axis)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    # pin the down-proj OUTPUT to the expert layout first so SPMD keeps the
    # einsum in expert placement, THEN reshard to token layout (the reverse
    # all-to-all).  A single token-layout constraint makes SPMD reshard the
    # (much larger) activations *before* the einsum instead.
    out_buf = ctx.hint(out_buf, g_spec, ep_axis, None, None)
    out_buf = ctx.hint(out_buf, ctx.batch, None, None, None)  # reverse a2a

    y = jax.vmap(lambda ob, sl, kp, od, w:
                 _combine_one_group(ob, sl, kp, od, w, ng, k))(
        out_buf, slot, keep, order, top_w.reshape(g, -1))
    y = y.reshape(b, s, d)

    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, act=cfg.act, ctx=ctx)
    if "dense" in p:
        y = y + apply_ffn(p["dense"], x, act=cfg.act, ctx=ctx)
    return y, aux.astype(jnp.float32)
