"""Dense feed-forward blocks: SwiGLU / GELU / squared-ReLU (nemotron)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallelism.ctx import NULL_CTX, ShardCtx


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    si, so = d_model ** -0.5, d_ff ** -0.5
    if act == "swiglu":
        return {
            "wi_gate": (si * jax.random.normal(ks[0], (d_model, d_ff))).astype(dtype),
            "wi_up": (si * jax.random.normal(ks[1], (d_model, d_ff))).astype(dtype),
            "wo": (so * jax.random.normal(ks[2], (d_ff, d_model))).astype(dtype),
        }
    return {
        "wi": (si * jax.random.normal(ks[0], (d_model, d_ff))).astype(dtype),
        "wo": (so * jax.random.normal(ks[1], (d_ff, d_model))).astype(dtype),
    }


def apply_ffn(p: dict, x, *, act: str, ctx: ShardCtx = NULL_CTX):
    ff_axis = ctx.tp_if(p["wo"].shape[0])
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        if act == "gelu":
            h = jax.nn.gelu(h)
        elif act == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:
            raise ValueError(act)
    h = ctx.hint(h, ctx.batch, None, ff_axis)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
