"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

`input_specs()` supplies precomputed mel-frame embeddings (B, Senc, d) —
the conv1d frontend is a stub per the assignment.  The decoder uses a
learned positional table sized at init (`max_dec_len`), self-attention with
a KV cache and cross-attention against the encoder output.  Embeddings are
tied (logits = h @ emb.T).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import attention as attn
from repro.models.layers.common import apply_norm, init_norm, \
    sinusoidal_embedding
from repro.models.layers.ffn import apply_ffn, init_ffn
from repro.models.lm import VOCAB_PAD
from repro.parallelism.ctx import NULL_CTX, ShardCtx

ENC_LEN = 1500  # 30 s of audio at 50 Hz after the (stubbed) conv frontend


def _init_enc_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn.init_attention(ks[0], cfg, dtype),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_dec_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "self_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "self_attn": attn.init_attention(ks[0], cfg, dtype),
        "cross_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "cross_attn": attn.init_attention(ks[1], cfg, dtype, cross=True),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_whisper(key, cfg: ArchConfig, dtype=jnp.float32,
                 max_dec_len: int = 4096) -> dict:
    vp = cfg.padded_vocab(VOCAB_PAD)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": {"emb": (0.02 * jax.random.normal(
            ks[2], (vp, cfg.d_model))).astype(dtype)},
        "pos_dec": (0.01 * jax.random.normal(
            ks[3], (max_dec_len, cfg.d_model))).astype(dtype),
        "enc_blocks": jax.vmap(partial(_init_enc_block, cfg=cfg,
                                       dtype=dtype))(enc_keys),
        "enc_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(partial(_init_dec_block, cfg=cfg,
                                       dtype=dtype))(dec_keys),
        "dec_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, frames, *, cfg: ArchConfig, ctx: ShardCtx):
    """frames: (B, Senc, d) precomputed embeddings -> (B, Senc, d)."""
    b, s, d = frames.shape
    x = frames + sinusoidal_embedding(s, d, frames.dtype)[None]
    x = ctx.hint(x, ctx.batch, None, None)
    nk, eps = cfg.norm, cfg.norm_eps
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    @jax.checkpoint
    def block(p, x):
        h = apply_norm(p["attn_norm"], x, kind=nk, eps=eps)
        x = x + attn.attention_train(p["attn"], h, cfg=cfg, ctx=ctx,
                                     positions=positions, causal=False)
        h = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
        x = x + apply_ffn(p["mlp"], h, act=cfg.act, ctx=ctx)
        return x

    def body(x, p):
        return block(p, x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, kind=nk, eps=eps)


# ---------------------------------------------------------------------------
# decoder — train / prefill / decode
# ---------------------------------------------------------------------------

def _dec_embed(params, tokens, offset, ctx):
    x = jnp.take(params["embed"]["emb"], tokens, axis=0)
    s = tokens.shape[1]
    pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], offset, s, axis=0)
    return ctx.hint(x + pos[None].astype(x.dtype), ctx.batch, None, None)


def decoder_train(params, tokens, enc_out, *, cfg: ArchConfig,
                  ctx: ShardCtx):
    """tokens: (B, Sd) -> hidden (B, Sd, d)."""
    b, s = tokens.shape
    x = _dec_embed(params, tokens, 0, ctx)
    nk, eps = cfg.norm, cfg.norm_eps
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def block(p, x):
        h = apply_norm(p["self_norm"], x, kind=nk, eps=eps)
        x = x + attn.attention_train(p["self_attn"], h, cfg=cfg, ctx=ctx,
                                     positions=positions, causal=True)
        h = apply_norm(p["cross_norm"], x, kind=nk, eps=eps)
        x = x + attn.cross_attention_train(p["cross_attn"], h, enc_out,
                                           cfg=cfg, ctx=ctx)
        h = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
        x = x + apply_ffn(p["mlp"], h, act=cfg.act, ctx=ctx)
        return x

    blk = jax.checkpoint(block)

    def body(x, p):
        return blk(p, x), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return apply_norm(params["dec_norm"], x, kind=nk, eps=eps)


def init_whisper_cache(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.float32) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    n = cfg.n_layers
    return {
        "len": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros((n, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((n, batch, max_len, kv, hd), dtype),
        "ck": jnp.zeros((n, batch, ENC_LEN, kv, hd), dtype),
        "cv": jnp.zeros((n, batch, ENC_LEN, kv, hd), dtype),
    }


def whisper_prefill(params, batch, *, cfg: ArchConfig, ctx: ShardCtx,
                    max_len: int = 0):
    """batch: {'frames': (B,Senc,d), 'tokens': (B,Sd)}.
    Returns (last logits, cache)."""
    enc_out = encode(params, batch["frames"], cfg=cfg, ctx=ctx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    x = _dec_embed(params, tokens, 0, ctx)
    nk, eps = cfg.norm, cfg.norm_eps
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pad = max_len - s

    def padS(a):
        if pad == 0:
            return a
        cfgpad = [(0, 0)] * a.ndim
        cfgpad[1] = (0, pad)
        return jnp.pad(a, cfgpad)

    def body(x, p):
        h = apply_norm(p["self_norm"], x, kind=nk, eps=eps)
        y, (kc, vc) = attn.attention_train(p["self_attn"], h, cfg=cfg,
                                           ctx=ctx, positions=positions,
                                           causal=True, return_kv=True)
        x = x + y
        h = apply_norm(p["cross_norm"], x, kind=nk, eps=eps)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                        p["cross_attn"]["wk"].astype(x.dtype))
        cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                        p["cross_attn"]["wv"].astype(x.dtype))
        x = x + attn.cross_attention_decode(p["cross_attn"], h, ck, cv,
                                            cfg=cfg, ctx=ctx)
        h = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
        x = x + apply_ffn(p["mlp"], h, act=cfg.act, ctx=ctx)
        return x, {"k": padS(kc).astype(x.dtype),
                   "v": padS(vc).astype(x.dtype),
                   "ck": ck.astype(x.dtype), "cv": cv.astype(x.dtype)}

    x, entries = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, kind=nk, eps=eps)
    logits = (x[:, -1] @ params["embed"]["emb"].T.astype(x.dtype)
              ).astype(jnp.float32)
    cache = {"len": jnp.full((b,), s, jnp.int32), **entries}
    return logits, cache


def whisper_decode(params, cache, batch, *, cfg: ArchConfig, ctx: ShardCtx):
    """One decode step. batch['tokens']: (B,1)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    cache_len = cache["len"]
    x = jnp.take(params["embed"]["emb"], tokens, axis=0)
    pos = jnp.take(params["pos_dec"], cache_len, axis=0)[:, None]
    x = ctx.hint(x + pos.astype(x.dtype), ctx.batch, None, None)
    nk, eps = cfg.norm, cfg.norm_eps

    def body(x, xs):
        p, ck_, cv_, kc, vc = xs
        h = apply_norm(p["self_norm"], x, kind=nk, eps=eps)
        y, nkc, nvc = attn.attention_decode(p["self_attn"], h, kc, vc,
                                            cfg=cfg, ctx=ctx,
                                            cache_len=cache_len)
        x = x + y
        h = apply_norm(p["cross_norm"], x, kind=nk, eps=eps)
        x = x + attn.cross_attention_decode(p["cross_attn"], h, ck_, cv_,
                                            cfg=cfg, ctx=ctx)
        h = apply_norm(p["mlp_norm"], x, kind=nk, eps=eps)
        x = x + apply_ffn(p["mlp"], h, act=cfg.act, ctx=ctx)
        return x, (nkc, nvc)

    x, (nk_all, nv_all) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["ck"], cache["cv"],
                  cache["k"], cache["v"]))
    x = apply_norm(params["dec_norm"], x, kind=nk, eps=eps)
    logits = (x[:, -1] @ params["embed"]["emb"].T.astype(x.dtype)
              ).astype(jnp.float32)
    new_cache = {"len": cache_len + 1, "k": nk_all, "v": nv_all,
                 "ck": cache["ck"], "cv": cache["cv"]}
    return logits, new_cache
