"""Sequence-chunked vocab-parallel cross-entropy.

Logits are never materialized for the full sequence: the head matmul and
log-sum-exp run per sequence chunk (peak activation = B×chunk×V instead of
B×S×V), with the vocab axis TP-sharded — reductions over the sharded vocab
axis lower to all-reduces under GSPMD.  Labels == -1 are masked out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallelism.ctx import NULL_CTX, ShardCtx


def chunked_cross_entropy(hidden, head_w, labels, *, ctx: ShardCtx = NULL_CTX,
                          chunk: int = 512):
    """hidden: (B,S,d); head_w: (d,V); labels: (B,S) int32 (-1 = pad)."""
    b, s, d = hidden.shape
    v = head_w.shape[1]
    c = min(chunk, s)
    if s % c:
        c = s  # fall back to single-shot for odd lengths
    nc = s // c

    def one_chunk(start):
        h = jax.lax.dynamic_slice_in_dim(hidden, start, c, axis=1)
        l = jax.lax.dynamic_slice_in_dim(labels, start, c, axis=1)
        logits = jnp.einsum("bcd,dv->bcv", h, head_w.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        logits = ctx.hint(logits, ctx.batch, None,
                          ctx.tp_if(v) if head_w.ndim == 2 else None)
        lse = jax.nn.logsumexp(logits, axis=-1)                    # (B,c)
        mask_v = jnp.arange(v, dtype=jnp.int32)[None, None, :] == \
            l[..., None]
        gold = jnp.sum(jnp.where(mask_v, logits, 0.0), axis=-1)    # (B,c)
        valid = l >= 0
        ce = jnp.where(valid, lse - gold, 0.0)
        return ce.sum(), valid.sum()

    def body(carry, i):
        tot, cnt = carry
        ls, n = one_chunk(i * c)
        return (tot + ls, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
