"""Checkpointing: mesh-agnostic save/restore with async writes.

Checkpoints are flat ``.npz`` files keyed by pytree path plus a JSON
manifest — saved arrays are fully replicated host values, so a checkpoint
written on one mesh restores onto any other (elastic re-sharding: the
restore path ``device_put``s each leaf with the *target* sharding).
Writes go to a temp file + atomic rename; ``save_async`` overlaps the write
with the next training step.  ``latest_step`` + replayable data pipeline
give restart-after-failure with bit-identical continuation
(tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, step: int, tree) -> str:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(path, f".tmp-{step}.npz")
    final = os.path.join(path, f"step-{step:08d}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"latest_step": step, "time": time.time(),
                   "n_arrays": len(flat)}, f)
    return final


class AsyncSaver:
    """Overlaps checkpoint writes with compute (one in flight)."""

    def __init__(self):
        self._thread = None

    def save_async(self, path: str, step: int, tree):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # sync copy
        self._thread = threading.Thread(
            target=save, args=(path, step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> int | None:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        return json.load(f)["latest_step"]


def restore(path: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; optionally device_put with
    target shardings (elastic: any mesh)."""
    fname = os.path.join(path, f"step-{step:08d}.npz")
    data = np.load(fname)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path_k, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
