"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every other
layer (16 experts, top-2). [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register, shrink

# 8-sublayer period with the single attention layer at index 4 (1:7 ratio);
# MoE replaces the MLP on every odd sublayer.
PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = register(
    ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        norm="rmsnorm",
        rope_mode="none",          # jamba uses no positional encoding
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336,
                      layer_mode="alternate"),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        block_pattern=PATTERN,
        source="arXiv:2403.19887",
    ),
    lambda: shrink(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=192,
                      layer_mode="alternate"),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2, dt_rank=8)),
)
