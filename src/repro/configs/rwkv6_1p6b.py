"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig, RWKVConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,           # d_model / head_size
        n_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        rope_mode="none",
        norm="layernorm",
        rwkv=RWKVConfig(head_size=64, decay_lora_rank=64, mix_lora_rank=32),
        source="arXiv:2404.05892",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=224, vocab_size=512,
        rwkv=RWKVConfig(head_size=16, decay_lora_rank=8, mix_lora_rank=4)),
)
