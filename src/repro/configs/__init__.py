from repro.configs.base import (
    SHAPES, ArchConfig, MLAConfig, MoEConfig, RWKVConfig, ShapeSpec, SSMConfig,
    get_config, get_reduced, list_archs,
)

__all__ = [
    "SHAPES", "ArchConfig", "MLAConfig", "MoEConfig", "RWKVConfig",
    "ShapeSpec", "SSMConfig", "get_config", "get_reduced", "list_archs",
]
