"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
        source="arXiv:2404.14219",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=224, vocab_size=512),
)
