"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        rope_theta=10_000.0,
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True, layer_mode="all"),
        source="hf:Snowflake/snowflake-arctic-base",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      dense_residual=True, layer_mode="all")),
)
