"""Architecture / shape configuration system.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published dims) and ``reduced()`` (a tiny same-family
variant for CPU smoke tests).  Shapes (seq_len x global_batch cells) are
global and owned here; each config reports which cells apply to it.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Shape cells (assigned input-shape set, shared by every LM-family arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode' | 'long_decode'

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")

    @property
    def tokens(self) -> int:
        """Tokens processed per step (decode steps emit one token/sequence)."""
        if self.is_decode:
            return self.global_batch
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0          # deepseek-v3 shared expert
    dense_residual: bool = False       # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # which layers are MoE. 'all' | 'alternate' (odd layers) | 'after_prefix'
    layer_mode: str = "all"
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 dims (used by jamba's mamba sublayers)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora_rank: int = 64
    mix_lora_rank: int = 32


# ---------------------------------------------------------------------------
# Main architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_mode: str = "rope"           # rope | mrope | sinusoidal | none
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "swiglu"               # swiglu | gelu
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # dense transformer layers before the MoE stack (deepseek-v3: 3)
    n_dense_prefix: int = 0
    # hybrid (jamba): per-period sublayer pattern, e.g. 8 entries; n_layers
    # must be divisible by len(block_pattern).  Entries: 'attn' | 'mamba'.
    block_pattern: Optional[tuple[str, ...]] = None

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # frontend stubs: 'audio' -> precomputed frame embeddings,
    # 'vision' -> precomputed patch embeddings, '' -> token ids
    frontend: str = ""

    # source provenance (from the assignment table)
    source: str = ""

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def padded_vocab(self, multiple: int = 32) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode cell?"""
        return self.family in ("ssm", "hybrid")

    def supports(self, shape: ShapeSpec) -> bool:
        if shape.kind == "long_decode":
            return self.sub_quadratic
        return True

    def cells(self) -> list[ShapeSpec]:
        return [s for s in SHAPES.values() if self.supports(s)]

    def skipped_cells(self) -> list[tuple[ShapeSpec, str]]:
        out = []
        for s in SHAPES.values():
            if not self.supports(s):
                out.append((s, "long_500k requires sub-quadratic attention; "
                               f"{self.name} is pure full-attention"))
        return out

    # --- parameter counting (for MODEL_FLOPS = 6*N*D) -----------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; active_only counts top-k routed experts."""
        d, hd = self.d_model, self.resolved_head_dim
        V = self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                q = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
                kv += m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                o = self.n_heads * m.v_head_dim * d
                return q + kv + o
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + b

        def dense_ffn() -> int:
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * self.d_ff

        def moe_ffn(active: bool) -> int:
            m = self.moe
            assert m is not None
            mult = 3 if self.act == "swiglu" else 2
            n_e = m.top_k if active else m.n_experts
            p = n_e * mult * d * m.d_ff_expert
            p += m.n_shared_experts * mult * d * m.d_ff_expert
            if m.dense_residual:
                p += dense_ffn()
            p += d * m.n_experts  # router
            return p

        def mamba_params() -> int:
            s = self.ssm
            assert s is not None
            di = s.expand * d
            p = 2 * d * di                      # in_proj (x, z)
            p += di * s.d_conv                  # depthwise conv
            p += di * (s.dt_rank + 2 * s.d_state)  # x_proj
            p += s.dt_rank * di                 # dt_proj
            p += di * s.d_state + di            # A_log, D
            p += di * d                         # out_proj
            return p

        def rwkv_params() -> int:
            r = self.rwkv
            assert r is not None
            tm = 4 * d * d + d * d              # r,k,v,g + output
            tm += d * r.decay_lora_rank * 2     # decay lora
            tm += 6 * d * r.mix_lora_rank * 2   # ddlerp loras (approx)
            cm = d * self.d_ff + self.d_ff * d + d * d  # channel mix k,v,r
            return tm + cm

        total = emb + head
        n_moe, n_dense = 0, 0
        pattern = self.block_pattern
        for layer in range(self.n_layers):
            if pattern is not None:
                sub = pattern[layer % len(pattern)]
                total += attn_params() if sub == "attn" else mamba_params()
                if self.moe is not None and self.moe.layer_mode == "alternate":
                    if layer % 2 == 1:
                        n_moe += 1
                    else:
                        n_dense += 1
                else:
                    n_dense += 1
                continue
            if self.family == "ssm":
                # channel-mix is already the FFN — no extra dense MLP.
                total += rwkv_params()
                continue
            total += attn_params()
            if self.moe is not None and layer >= self.n_dense_prefix:
                n_moe += 1
            else:
                n_dense += 1
        if self.enc_dec:
            # encoder: self-attn + ffn; decoder already counted above,
            # add cross-attention for decoder layers.
            total += self.n_enc_layers * (attn_params() + dense_ffn())
            total += self.n_layers * attn_params()  # cross attn
        total += n_dense * dense_ffn()
        if n_moe:
            total += n_moe * moe_ffn(active=active_only)
        return total

    def model_flops(self, shape: ShapeSpec) -> float:
        """6*N*D with N = active params (MoE counts top-k)."""
        n = self.param_count(active_only=True)
        mult = 6.0 if shape.kind == "train" else 2.0
        return mult * n * shape.tokens


# registry -------------------------------------------------------------------

_REGISTRY: dict[str, "tuple"] = {}


def register(config: ArchConfig, reduced_fn) -> ArchConfig:
    _REGISTRY[config.name] = (config, reduced_fn)
    return config


def get_config(name: str) -> ArchConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name][0]


def get_reduced(name: str) -> ArchConfig:
    _load_all()
    return _REGISTRY[name][1]()


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False

_ARCH_MODULES = [
    "codeqwen15_7b", "qwen2_72b", "phi3_medium_14b", "minitron_8b",
    "rwkv6_1p6b", "qwen2_vl_2b", "jamba_v01_52b", "arctic_480b",
    "deepseek_v3_671b", "whisper_base",
]


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def shrink(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Build a reduced same-family config for smoke tests."""
    return dataclasses.replace(cfg, **overrides)
