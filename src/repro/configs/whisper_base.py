"""whisper-base [audio] — encoder-decoder; conv frontend STUBBED
(input_specs() provides precomputed frame embeddings).
Vocab 51865 is padded to a TP-divisible multiple in the embedding table.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,               # decoder layers
        n_enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        enc_dec=True,
        tie_embeddings=True,
        norm="layernorm",
        act="gelu",
        rope_mode="sinusoidal",
        frontend="audio",
        source="arXiv:2212.04356",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512),
)
