"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8.
(MTP head noted in DESIGN.md; not part of the lowered step.)
[arXiv:2412.19437; hf]"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,               # dense-prefix MLP width (published)
        vocab_size=129280,
        rope_theta=10_000.0,
        n_dense_prefix=3,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                      n_shared_experts=1, layer_mode="after_prefix"),
        source="arXiv:2412.19437",
    ),
    lambda: shrink(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=192, vocab_size=512, n_dense_prefix=1,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared_experts=1, layer_mode="after_prefix")),
)
