"""codeqwen1.5-7b [dense] — qwen1.5 arch. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/CodeQwen1.5-7B",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab_size=512),
)
