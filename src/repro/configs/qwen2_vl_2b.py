"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision frontend stubbed:
input_specs() provides precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        rope_mode="mrope",
        rope_theta=1_000_000.0,
        frontend="vision",
        source="arXiv:2409.12191",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=256, vocab_size=512),
)
