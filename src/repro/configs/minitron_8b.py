"""minitron-8b [dense] — pruned nemotron (squared-ReLU MLP, LayerNorm).
[arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig, register, shrink

CONFIG = register(
    ArchConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        norm="layernorm",
        act="relu2",
        rope_theta=10_000.0,
        source="arXiv:2407.14679",
    ),
    lambda: shrink(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512),
)
