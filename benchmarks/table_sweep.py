"""Table-sweep throughput: what do the per-class timing-table leaves cost?

Two sweeps over the same lane count, same workload, same compiled-engine
shape:

  · ``tables``  — the typed DynConfig as-is: per-lane (N_CLASSES,)
    ``core.lat``/``core.disp`` tables are traced inputs, each lane carries
    a DIFFERENT per-class latency point (launch/dse.py:sample_table_grid);
  · ``scalar``  — the pre-refactor representation emulated: the tables
    are baked into the program as compile-time constants (every lane
    shares the default class tables) and only the scalar leaves + sched
    remain traced.

The delta prices the table-valued refactor's runtime cost (it should be
noise: two small gathers per issued instruction either way — against a
20+×-larger sweepable design space per lane).  Reports lanes/sec for
both, like the dse suite.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import MAX_CYCLES, SIM_SCALE, save_json, timeit
from repro.core.batch import stack_kernels
from repro.core.engine import run_workload
from repro.core.parallel import make_sm_runner
from repro.core.sweep import batched_init, make_sweep_runner, stack_dyn
from repro.launch.dse import sample_table_grid
from repro.sim.config import (DISPATCH_OF_CLASS, LATENCY_OF_CLASS, TINY)
from repro.sim.state import init_state
from repro.workloads import make_workload

N_CONFIGS = 8
BENCH = "hotspot"


def run() -> list[dict]:
    w = make_workload(BENCH, scale=SIM_SCALE)
    cfgs = sample_table_grid(TINY, N_CONFIGS,
                             sample_lat=[("fp32", 2, 16), ("sfu", 8, 32)],
                             sample_disp=[("tensor", 1, 4)])
    scfg, dyn_batch = stack_dyn(cfgs)
    packed = [k.pack() for k in w.kernels]
    max_cycles = min(MAX_CYCLES, 1 << 15)
    sm_runner = make_sm_runner(scfg, "vmap")

    # table-valued: the whole DynConfig (tables included) is traced
    stacked = stack_kernels(packed)
    batched = make_sweep_runner(scfg, max_cycles=max_cycles)
    t_tab = timeit(
        lambda: jax.block_until_ready(
            batched(batched_init(scfg, N_CONFIGS), stacked, dyn_batch)),
        warmup=1, iters=3)

    # scalar-only: bake the default class tables in as constants; the lanes
    # then differ only in scalar knobs (the old 7-scalar pytree, emulated)
    const_lat = jnp.asarray(LATENCY_OF_CLASS, jnp.int32)
    const_disp = jnp.asarray(DISPATCH_OF_CLASS, jnp.int32)

    def run_one_scalar(dyn):
        core = dataclasses.replace(dyn.core, lat=const_lat, disp=const_disp)
        d = dataclasses.replace(dyn, core=core)
        return run_workload(init_state(scfg), packed, scfg, d, sm_runner,
                            max_cycles)

    scalar_batched = jax.jit(jax.vmap(run_one_scalar))
    t_sc = timeit(
        lambda: jax.block_until_ready(scalar_batched(dyn_batch)),
        warmup=1, iters=3)

    rows = [{
        "name": f"tables/table_valued_x{N_CONFIGS}",
        "us_per_call": t_tab * 1e6,
        "derived": f"lanes_per_s={N_CONFIGS / t_tab:.2f}",
    }, {
        "name": f"tables/scalar_only_x{N_CONFIGS}",
        "us_per_call": t_sc * 1e6,
        "derived": (f"lanes_per_s={N_CONFIGS / t_sc:.2f} "
                    f"table_overhead={t_tab / t_sc:.2f}x"),
    }]
    save_json("table_sweep", {
        "n_configs": N_CONFIGS, "bench": BENCH, "scale": SIM_SCALE,
        "max_cycles": max_cycles, "t_tables_s": t_tab, "t_scalar_s": t_sc,
        "table_overhead": t_tab / t_sc,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
