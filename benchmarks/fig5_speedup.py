"""Fig. 5 analogue — parallel speed-up vs. "thread" count.

Three complementary measurements (this container has ONE physical core, so
wall-clock multi-device scaling is not physically observable — DESIGN.md §7):

  a. measured: sequential (lax.map over SMs) vs vectorized (vmap) wall time
     — the single-chip SIMD speed-up of the parallel region;
  b. measured: sharded-mode wall time at 1/2/4/8/16 host devices
     (subprocess per count; flat on one core, reported honestly);
  c. modeled: Amdahl speed-up from the *measured deterministic work
     distribution* — parallel work = per-SM active-warp-cycles, serial work
     = memory-system events — reproducing the paper's curve shapes
     (lavaMD near-linear, myocyte flat, strong correlation with Fig. 1).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import (DEFAULT_BENCHES, MAX_CYCLES, SIM_SCALE,
                               run_shard_worker, save_json)
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner, sm_permutation
from repro.sim.config import RTX3080TI
from repro.workloads import make_workload

THREADS = (2, 4, 8, 16)


def modeled_speedup(per_sm_work: np.ndarray, serial_work: float,
                    n_dev: int, policy: str, cfg) -> float:
    perm = sm_permutation(cfg, n_dev, policy)
    w = per_sm_work[perm].reshape(n_dev, -1).sum(axis=1)
    total = per_sm_work.sum() + serial_work
    par = w.max() + serial_work
    return float(total / max(par, 1))


SHARD_BENCHES = ("lavaMD", "myocyte", "cut_1", "sssp")


def run(benches=None, shard_devices=(2, 8, 16),
        measure_shard: bool = True) -> list[dict]:
    cfg = RTX3080TI
    rows = []
    for name in benches or DEFAULT_BENCHES:
        w = make_workload(name, scale=SIM_SCALE)

        def wall(mode):
            runner = make_sm_runner(cfg, mode)
            t0 = time.perf_counter()
            st = simulate(w, cfg, runner, max_cycles=MAX_CYCLES)
            jax.block_until_ready(st["ctrl"]["total_cycles"])
            return time.perf_counter() - t0, st

        t_seq, st = wall("seq")
        t_vmap, st2 = wall("vmap")
        out = S.finalize(st)
        assert S.comparable(out) == S.comparable(S.finalize(st2))
        per_sm = out["warp_cycles_per_sm"].astype(np.float64)
        serial = float(out["l2_hit"] + out["l2_miss"] + out["dram_req"])
        model = {d: round(modeled_speedup(per_sm, serial, d, "static", cfg),
                          2) for d in THREADS}
        rows.append({
            "name": f"fig5/{name}/vectorize",
            "us_per_call": t_vmap * 1e6,
            "derived": f"seq_s={t_seq:.2f};speedup={t_seq / t_vmap:.2f}",
        })
        rows.append({
            "name": f"fig5/{name}/modeled",
            "us_per_call": 0.0,
            "derived": ";".join(f"x{d}={v}" for d, v in model.items()),
        })
        if measure_shard and name in SHARD_BENCHES:
            walls = {}
            for d in shard_devices:
                try:
                    r = run_shard_worker(name, d)
                    walls[d] = round(r["wall_s"], 3)
                except Exception as e:  # noqa: BLE001
                    walls[d] = f"err:{type(e).__name__}"
            rows.append({
                "name": f"fig5/{name}/sharded_wall",
                "us_per_call": 0.0,
                "derived": ";".join(f"d{d}={v}" for d, v in walls.items()),
            })
    save_json("fig5_speedup", {"rows": rows})
    return rows
