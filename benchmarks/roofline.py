"""§Roofline table builder — reads experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import REPO, save_json

DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(mesh: str = "16x16") -> str:
    recs = load_records(mesh)
    lines = ["| arch | shape | dom | compute_s | memory_s | coll_s | "
             "useful/HLO | roofline | peak GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP "
                         f"| — | — | — | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} "
            f"| {r['compute_term_s']:.3g} | {r['memory_term_s']:.3g} "
            f"| {r['collective_term_s']:.3g} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['peak_bytes_per_dev'] / 2**30:.2f} |")
    return "\n".join(lines)


def run() -> list[dict]:
    rows = []
    for mesh in ("16x16", "2x16x16"):
        recs = [r for r in load_records(mesh) if not r.get("skipped")
                and "error" not in r]
        for r in recs:
            rows.append({
                "name": f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                "us_per_call": r["step_bound_s"] * 1e6,
                "derived": f"dom={r['dominant']};"
                           f"frac={r['roofline_fraction']:.3f};"
                           f"useful={r['useful_flops_ratio']:.2f}",
            })
    save_json("roofline", {"rows": rows})
    return rows
