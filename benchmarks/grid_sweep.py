"""Grid-sweep throughput: (workload × config) lanes in ONE compiled
program vs a Python loop of solo workload programs.

The batched path pads + stacks W zoo workloads (core/batch.py), vmaps
them against C configs and dispatches one XLA program for the whole grid;
the loop path runs W jitted solo programs (dyn traced, so each workload
compiles once and serves all its configs) but pays W×C sequential device
dispatches.  Reports (workload×config)-lanes/sec for both and the
speedup.  Emits JSON into experiments/bench/ like the other benchmarks.

Caveat the numbers honestly: vmap lanes advance in lock-step, so every
lane pays the slowest lane's quantum count.  On a single CPU core with
cycle-skewed zoo workloads that straggler tax can make the batched grid
SLOWER than the loop (speedup < 1); the batched form wins on parallel
backends and on homogeneous lanes (cf. the dse benchmark, where all
lanes share one workload).
"""
from __future__ import annotations

import jax

from benchmarks.common import (MAX_CYCLES, SIM_SCALE, grid_workload_names,
                               save_json, timeit)
from repro.core.batch import (check_workload_fits, stack_kernels,
                              stack_workloads)
from repro.core.engine import run_workload_stacked
from repro.core.parallel import make_sm_runner
from repro.core.sweep import batched_init, make_grid_runner, stack_dyn
from repro.launch.dse import default_grid
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.sim.workloads import resolve_workload

N_WORKLOADS = 4
N_CONFIGS = 4


def run() -> list[dict]:
    # names may mix namespaces (zoo / trace:<x> / Table-2) — set
    # REPRO_GRID_WORKLOADS=trace:vecadd,gemm_tiled,... to rebench on
    # real-trace rows; trace rows keep their real CTA counts
    names = grid_workload_names(N_WORKLOADS)
    workloads = [resolve_workload(
        n, scale=1.0 if n.startswith("trace:") else SIM_SCALE)
        for n in names]
    cfgs = default_grid(TINY, N_CONFIGS)
    scfg, dyn_batch = stack_dyn(cfgs)
    for w in workloads:
        check_workload_fits(scfg, w)
    stacked = stack_workloads(workloads)
    max_cycles = min(MAX_CYCLES, 1 << 15)
    n_w = len(workloads)
    lanes = n_w * N_CONFIGS

    # donated state: a fresh (W, C) batch per timed call
    batched = make_grid_runner(scfg, max_cycles=max_cycles)
    t_batch = timeit(
        lambda: jax.block_until_ready(batched(
            batched_init(scfg, n_w, N_CONFIGS), stacked, dyn_batch)),
        warmup=1, iters=3)

    # loop path: one jitted program PER workload (its own stacked shape),
    # dyn traced so all C configs share that compilation
    sm_runner = make_sm_runner(scfg, "vmap")
    solos = []
    for w in workloads:
        wk = stack_kernels([k.pack() for k in w.kernels])
        solos.append(jax.jit(
            lambda dyn, wk=wk: run_workload_stacked(
                init_state(scfg), wk, scfg, dyn, sm_runner, max_cycles)))
    dyns = [split_config(cfg)[1] for cfg in cfgs]

    def loop():
        outs = [solo(d)["ctrl"]["total_cycles"]
                for solo in solos for d in dyns]
        jax.block_until_ready(outs)
        return outs

    t_loop = timeit(loop, warmup=1, iters=3)

    rows = [{
        "name": f"grid/batched_{n_w}x{N_CONFIGS}",
        "us_per_call": t_batch * 1e6,
        "derived": f"lanes_per_s={lanes / t_batch:.2f}",
    }, {
        "name": f"grid/loop_{n_w}x{N_CONFIGS}",
        "us_per_call": t_loop * 1e6,
        "derived": (f"lanes_per_s={lanes / t_loop:.2f} "
                    f"speedup={t_loop / t_batch:.2f}x"),
    }]
    save_json("grid_sweep", {
        "n_workloads": n_w, "n_configs": N_CONFIGS,
        "workloads": names, "scale": SIM_SCALE, "max_cycles": max_cycles,
        "t_batched_s": t_batch, "t_loop_s": t_loop,
        "speedup": t_loop / t_batch,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
