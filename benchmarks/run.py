"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes a standardized
``experiments/bench/BENCH_<suite>.json`` artifact per suite (schema:
suite, rows[{name, us_per_call, derived}], git_sha, date) — the files CI
uploads so the perf trajectory is comparable across commits.

  fig1  — single-thread simulation time per workload        (paper Fig. 1)
  fig5  — parallel speed-up vs thread/device count          (paper Fig. 5)
  fig6  — static vs dynamic scheduler                       (paper Fig. 6)
  fig7  — CTAs per kernel                                   (paper Fig. 7)
  det   — determinism across modes/devices/schedulers       (paper §1/§3)
  dse   — batched config sweep vs solo-run loop             (DSE layer)
  grid  — batched workloads × configs grid vs solo loop     (zoo frontend)
  packing — bucketed ragged packing vs monolithic vs solo loop, plus
            compile-cache cold/warm                         (RunPlan, PR 8)
  mesh  — distributed grid sweep vs 2-D ('cfg','sm') mesh shape
  tables — table-valued vs scalar-only dyn pytree lanes/sec (DynConfig)
  traces — real-trace ingest time + trace-row vs zoo-row lanes/sec
  search — analytic surrogate configs/sec vs engine lanes/sec, and
           search() vs exhaustive sweep wall clock       (core/search.py)
  serving — continuously batched sim server: jobs/sec, p50/p99 latency,
            warm vs cold, vs one-process-per-job       (core/service.py)
  roofline — per-(arch×shape×mesh) roofline terms           (§Roofline)
  kernels  — Pallas kernel microbenchmarks
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# runnable as `python benchmarks/run.py` from anywhere: the `benchmarks`
# package lives at the repo root, not under src/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def perf_gate() -> list:
    """Perf-trajectory gate (ROADMAP open item): compare the speedup
    ratios measured THIS run against the committed reference
    (benchmarks/perf_reference.json).  Each reference entry names a suite
    artifact under experiments/bench/ (``file``, default
    ``<key>_sweep.json``) and a ratio key inside it (``metric``, default
    ``speedup``); both sides of every ratio are timed on the same host in
    the same process, so machine speed cancels out.  A gated entry whose
    suite was not run this time is skipped with a note (the full bench
    run exercises them all).  Returns a list of failure strings; empty =
    gate passed."""
    import json

    here = os.path.dirname(os.path.abspath(__file__))
    ref_path = os.path.join(here, "perf_reference.json")
    with open(ref_path) as f:
        ref = json.load(f)
    fails = []
    for key, spec in ref.items():
        if key.startswith("_") or not isinstance(spec, dict):
            continue
        fname = spec.get("file", f"{key}_sweep.json")
        metric = spec.get("metric", "speedup")
        cur_path = os.path.join(here, "..", "experiments", "bench", fname)
        try:
            with open(cur_path) as f:
                cur = json.load(f)
        except FileNotFoundError:
            print(f"[gate] {key}: {fname} not produced this run — skipped "
                  f"(run --only {key} or the full suite to gate it)")
            continue
        tol = float(spec.get("tolerance", 0.25))
        floor = float(spec[metric]) * (1.0 - tol)
        got = float(cur[metric])
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"[gate] {key} {metric}: {got:.3f}x (reference "
              f"{spec[metric]}x, floor {floor:.3f}x at -{tol:.0%}) "
              f"{verdict}")
        if got < floor:
            fails.append(
                f"{key} {metric} {got:.3f}x < floor {floor:.3f}x — "
                f"regressed vs benchmarks/perf_reference.json; if "
                "intentional, update the reference with the measured "
                "value")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: fig1 fig5 fig6 fig7 det dse grid packing "
                         "mesh tables traces search serving roofline "
                         "kernels")
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess device sweeps")
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) when this run's batched-grid "
                         "speedup regresses >tolerance vs "
                         "benchmarks/perf_reference.json")
    args = ap.parse_args()
    if args.gate and args.only is not None:
        # the gate needs the gated suites' artifacts
        args.only = list(args.only) + [
            s for s in ("grid", "packing", "search", "serving")
            if s not in args.only]

    from benchmarks import (determinism, dse_sweep, fig1_sim_time,
                            fig5_speedup, fig6_scheduler, fig7_ctas,
                            grid_sweep, kernels_bench, mesh_sweep, packing,
                            roofline, search_bench, serving, table_sweep,
                            traces_bench)
    from benchmarks.common import save_bench

    suites = {
        "fig7": fig7_ctas.run,
        "roofline": roofline.run,
        "kernels": kernels_bench.run,
        "fig1": fig1_sim_time.run,
        "fig6": fig6_scheduler.run,
        "fig5": (lambda: fig5_speedup.run(measure_shard=not args.fast)),
        "det": determinism.run,
        "dse": dse_sweep.run,
        "grid": grid_sweep.run,
        "packing": packing.run,
        "mesh": (lambda: mesh_sweep.run(fast=args.fast)),
        "tables": table_sweep.run,
        "traces": traces_bench.run,
        "search": search_bench.run,
        "serving": serving.run,
    }
    rows = []
    failed = False
    for name, fn in suites.items():
        if args.only and name not in args.only:
            continue
        try:
            suite_rows = fn()
        except Exception:  # noqa: BLE001
            failed = True
            traceback.print_exc()
            suite_rows = [{"name": name, "us_per_call": -1.0,
                           "derived": "ERROR"}]
        save_bench(name, suite_rows)
        rows.extend(suite_rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.gate:
        for msg in perf_gate():
            print(f"[gate] FAIL: {msg}")
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
