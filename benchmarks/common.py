"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO, "experiments", "bench")

# paper-suite subset used by default (full list via --full)
DEFAULT_BENCHES = ["myocyte", "lavaMD", "hotspot", "sssp", "cut_1", "cut_2",
                   "gemm", "nw"]
SIM_SCALE = float(os.environ.get("REPRO_SIM_SCALE", "0.03"))
MAX_CYCLES = int(os.environ.get("REPRO_SIM_MAX_CYCLES", str(1 << 17)))


def grid_workload_names(n: int) -> list:
    """Workload rows for the grid benchmarks: ``REPRO_GRID_WORKLOADS``
    (comma-separated; zoo names, ``trace:<x>`` and Table-2 names all
    resolve via sim/workloads.py:resolve_workload) or the first ``n``
    zoo entries."""
    env = os.environ.get("REPRO_GRID_WORKLOADS", "")
    if env:
        return [s for s in (t.strip() for t in env.split(",")) if s]
    from repro.sim.workloads import zoo_names
    return zoo_names()[:n]


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters


def save_json(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA", "")
    if not sha:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True,
                text=True, cwd=REPO, timeout=10).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            sha = ""
    return sha or "unknown"


def save_bench(suite: str, rows: list) -> str:
    """Standardized perf-trajectory artifact: BENCH_<suite>.json with the
    suite's rows plus the git sha, UTC date and HOST CONTEXT (hostname,
    device kind/count, XLA_FLAGS — core/telemetry.py:host_context), so
    CI-uploaded artifacts are comparable across commits and labeled across
    machines.  Also drops a ``bench_<suite>`` run manifest under
    experiments/runs/ so `launch/report.py list|summarize` sees bench runs
    next to launcher runs.  Returns the BENCH file path."""
    import datetime

    from repro.core.telemetry import host_context, write_manifest

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "rows": [{"name": r["name"], "us_per_call": r["us_per_call"],
                  "derived": r["derived"]} for r in rows],
        "git_sha": git_sha(),
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "host": host_context(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    write_manifest(f"bench_{suite}",
                   extra={"suite": suite, "rows": payload["rows"]})
    return path


def run_shard_worker(workload: str, devices: int, policy: str = "static",
                     exchange: str = "window", scale: float = SIM_SCALE,
                     timeout: int = 900) -> dict:
    """Run one sharded simulation in a subprocess with `devices` host
    devices (jax locks the device count per process)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "benchmarks.shard_worker",
           "--workload", workload, "--devices", str(devices),
           "--policy", policy, "--exchange", exchange,
           "--scale", str(scale), "--max-cycles", str(MAX_CYCLES)]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"shard worker failed: {out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])
