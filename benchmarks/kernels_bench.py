"""Pallas kernel microbenchmarks (interpret mode — correctness-path timing;
real MXU timing requires TPU hardware, see DESIGN.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import save_json, timeit


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    from repro.kernels.flash_attention.ops import (attention_ref_op,
                                                   flash_attention_op)
    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    t_k = timeit(lambda: jax.block_until_ready(
        flash_attention_op(q, q, q, causal=True)))
    t_r = timeit(lambda: jax.block_until_ready(
        attention_ref_op(q, q, q, causal=True)))
    rows.append({"name": "kernels/flash_attention", "us_per_call": t_k * 1e6,
                 "derived": f"ref_us={t_r * 1e6:.0f}"})

    from repro.kernels.wkv6.ops import wkv6_op
    from repro.kernels.wkv6.ref import wkv_ref_chunked
    r = jax.random.normal(key, (2, 256, 4, 64)) * 0.5
    w = -jnp.exp(jax.random.normal(key, (2, 256, 4, 64)))
    u = jax.random.normal(key, (4, 64)) * 0.3
    s0 = jnp.zeros((2, 4, 64, 64), jnp.float32)
    t_k = timeit(lambda: jax.block_until_ready(wkv6_op(r, r, r, w, u)[0]))
    ref = jax.jit(lambda: wkv_ref_chunked(r, r, r, w, u, s0)[0])
    t_r = timeit(lambda: jax.block_until_ready(ref()))
    rows.append({"name": "kernels/wkv6", "us_per_call": t_k * 1e6,
                 "derived": f"ref_us={t_r * 1e6:.0f}"})

    from repro.kernels.sm_issue.ops import issue_select_op
    from repro.kernels.sm_issue.ref import issue_select_ref
    import numpy as np
    from repro.sim.config import N_UNITS
    rng = np.random.default_rng(0)
    n_sm, W, SC, L = 80, 48, 4, 128
    args = (jnp.asarray(rng.integers(0, L, (n_sm, W)), jnp.int32),
            jnp.asarray(rng.random((n_sm, W)) < 0.7),
            jnp.asarray(rng.integers(0, 30, (n_sm, W)), jnp.int32),
            jnp.asarray(rng.integers(0, 2, (n_sm, W)), jnp.int32),
            jnp.asarray(rng.random((n_sm, W)) < 0.3),
            jnp.asarray(rng.integers(-1, W, (n_sm, SC)), jnp.int32),
            jnp.asarray(rng.integers(0, 20, (n_sm, SC, N_UNITS)), jnp.int32),
            jnp.asarray(rng.integers(0, 6, (L,)), jnp.int32),
            jnp.asarray(rng.random((L,)) < 0.5), 10)
    t_k = timeit(lambda: jax.block_until_ready(
        issue_select_op(*args, n_subcores=SC)))
    ref = jax.jit(lambda: issue_select_ref(*args, n_subcores=SC))
    t_r = timeit(lambda: jax.block_until_ready(ref()))
    rows.append({"name": "kernels/sm_issue", "us_per_call": t_k * 1e6,
                 "derived": f"ref_us={t_r * 1e6:.0f}"})
    save_json("kernels", {"rows": rows})
    return rows
