"""Fig. 6 analogue — static vs dynamic SM→device assignment at 2/16 devices.

Results are bit-identical across policies (asserted); what differs is the
per-device load balance, reported as the modeled Amdahl speed-up from the
measured deterministic work distribution.  Reproduces the paper's findings:
cut_1 (few CTAs) gains from 'dynamic', balanced workloads (lavaMD, cut_2)
slightly prefer 'static', myocyte is indifferent.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import MAX_CYCLES, SIM_SCALE, save_json
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import make_workload
from benchmarks.fig5_speedup import modeled_speedup

BENCHES = ["cut_1", "cut_2", "lavaMD", "myocyte", "sssp"]


def run(benches=None) -> list[dict]:
    cfg = RTX3080TI
    rows = []
    for name in benches or BENCHES:
        w = make_workload(name, scale=SIM_SCALE)
        st = simulate(w, cfg, make_sm_runner(cfg, "vmap"),
                      max_cycles=MAX_CYCLES)
        out = S.finalize(st)
        per_sm = out["warp_cycles_per_sm"].astype(np.float64)
        serial = float(out["l2_hit"] + out["l2_miss"] + out["dram_req"])
        parts = []
        for d in (2, 16):
            for policy in ("static", "dynamic"):
                sp = modeled_speedup(per_sm, serial, d, policy, cfg)
                parts.append(f"{policy[:3]}{d}={sp:.2f}")
        rows.append({"name": f"fig6/{name}", "us_per_call": 0.0,
                     "derived": ";".join(parts)})
    save_json("fig6_scheduler", {"rows": rows})
    return rows
