"""Fig. 7 analogue — CTAs per kernel per workload (at scale=1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.workloads import ALL_BENCHMARKS, make_workload


def run(benches=None) -> list[dict]:
    rows = []
    for name in benches or ALL_BENCHMARKS:
        w = make_workload(name, scale=1.0)
        ctas = w.ctas_per_kernel()
        rows.append({
            "name": f"fig7/{name}", "us_per_call": 0.0,
            "derived": f"kernels={len(ctas)};mean_ctas={np.mean(ctas):.0f};"
                       f"min={min(ctas)};max={max(ctas)}",
        })
    save_json("fig7_ctas", {"rows": rows})
    return rows
