"""Search-driven DSE throughput: the analytic fast path vs the engine.

Two comparisons, both on the SAME candidate space (SearchSpace.from_base
around TINY, core/search.py):

  · ``scorer`` — configs/sec of the analytical surrogate
    (core/analytic.py: one basis matmul over thousands of candidates) vs
    lanes/sec of a cycle-accurate ``sweep()`` over a small probe of the
    same space.  The acceptance bar for the fast path is ``ratio`` ≥ 100×
    (experiments/bench/search.json: ``analytic_ratio``).
  · ``end-to-end`` — wall clock of a full ``search()`` (propose → score
    N_SPACE candidates/round → verify top-k, SEARCH_ROUNDS rounds) vs an
    exhaustive cycle-accurate ``sweep()`` of N_SPACE candidates drawn
    from the same space with the same seed.  Their ``speedup`` ratio is
    what ``run.py --gate`` pins against benchmarks/perf_reference.json —
    the search must keep beating brute force by a wide margin, or the
    pruning has stopped paying for itself.

Both sides of every ratio run in this process on this host, so machine
speed cancels.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SIM_SCALE, save_json, timeit
from repro.core import analytic
from repro.core.plan import RunPlan
from repro.core.search import SearchSpace, search
from repro.core.sweep import sweep
from repro.sim import features as F
from repro.sim.config import TINY, split_config
from repro.workloads import make_workload

BENCH = "hotspot"
N_SCORE = 4096          # candidates per analytic scoring call
N_PROBE = 8             # cycle-accurate lanes in the probe sweep
N_SPACE = 64            # exhaustive-vs-search space size (end-to-end)
SEARCH_ROUNDS = 3
SEARCH_TOPK = 8
MAX_CYCLES = 1 << 14
SEED = 0


def run() -> list[dict]:
    base = TINY
    scfg, _ = split_config(base)
    w = make_workload(BENCH, scale=SIM_SCALE)
    feats = F.workload_features(w, scfg)
    space = SearchSpace.from_base(base)
    plan = RunPlan(max_cycles=MAX_CYCLES, search_rounds=SEARCH_ROUNDS,
                   search_topk=SEARCH_TOPK)

    # -- scorer: analytic configs/sec vs cycle-accurate lanes/sec -----------
    rng = np.random.Generator(np.random.PCG64(SEED))
    cands = space.sample(rng, N_SCORE)
    model = analytic.CostModel.default()
    t_score = timeit(lambda: model.predict(feats, cands),
                     warmup=1, iters=5)
    analytic_cps = N_SCORE / max(t_score, 1e-9)

    probe = [(scfg, analytic.decode(v)) for v in cands[:N_PROBE]]
    sweep(w, probe, plan=plan)                       # compile outside timing
    t_probe = timeit(lambda: sweep(w, probe, plan=plan), warmup=0, iters=3)
    engine_lps = N_PROBE / max(t_probe, 1e-9)
    ratio = analytic_cps / max(engine_lps, 1e-9)

    # -- end to end: search() vs exhaustive sweep of the same space ---------
    t0 = time.perf_counter()
    result = search(w, space, plan=plan, seed=SEED, base=base,
                    n_candidates=N_SPACE, calibrate_from=None)
    t_search = time.perf_counter() - t0

    rng = np.random.Generator(np.random.PCG64(SEED))
    lanes = [(scfg, analytic.decode(v))
             for v in space.sample(rng, N_SPACE)]
    t0 = time.perf_counter()
    exhaustive = sweep(w, lanes, plan=plan)
    t_exh = time.perf_counter() - t0
    exh_best = int(min(exhaustive.cycles))
    speedup = t_exh / max(t_search, 1e-9)

    rows = [{
        "name": f"search/analytic_x{N_SCORE}",
        "us_per_call": t_score * 1e6,
        "derived": f"cands_per_s={analytic_cps:.0f}",
    }, {
        "name": f"search/engine_x{N_PROBE}",
        "us_per_call": t_probe * 1e6,
        "derived": (f"lanes_per_s={engine_lps:.2f} "
                    f"analytic_ratio={ratio:.0f}x"),
    }, {
        "name": f"search/e2e_r{SEARCH_ROUNDS}k{SEARCH_TOPK}",
        "us_per_call": t_search * 1e6,
        "derived": (f"verified={result.n_verified}/"
                    f"{result.n_scored} best={result.best_cycles}"),
    }, {
        "name": f"search/exhaustive_x{N_SPACE}",
        "us_per_call": t_exh * 1e6,
        "derived": (f"best={exh_best} "
                    f"speedup={speedup:.2f}x"),
    }]
    save_json("search", {
        "bench": BENCH, "scale": SIM_SCALE, "max_cycles": MAX_CYCLES,
        "seed": SEED, "n_score": N_SCORE, "n_probe": N_PROBE,
        "n_space": N_SPACE, "rounds": SEARCH_ROUNDS, "topk": SEARCH_TOPK,
        "t_analytic_s": t_score, "t_probe_s": t_probe,
        "analytic_cands_per_s": analytic_cps,
        "engine_lanes_per_s": engine_lps, "analytic_ratio": ratio,
        "t_search_s": t_search, "t_exhaustive_s": t_exh,
        "search_best": result.best_cycles, "exhaustive_best": exh_best,
        "n_verified": result.n_verified,
        "calibration": result.model.calib,
        "speedup": speedup,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
