"""Serving bench: the continuously batched sim server vs everything else.

Measures the ROADMAP's simulation-as-a-service claim on real numbers:

  · cold server — first batch pays lower+compile for its buckets
  · warm server (threaded, production shape) — jobs/sec and the p50/p99
    end-to-end job latency (queue + execute; compile amortized away)
  · one-process-per-job — the same jobs each run in a fresh python
    process (interpreter + jax import + compile per job), the way
    pre-service users ran sweeps

The ``speedup`` ratio pinned by benchmarks/perf_reference.json (entry
``serving``, file serving.json) is one-process-per-job wall over warm-
server wall on the SAME job list — both sides timed on this host in this
run, so machine speed cancels.  REPRO_SERVE_PERJOB_JOBS trims how many
subprocess jobs the baseline pays for (default 3; each one recompiles).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import REPO, SIM_SCALE, save_json

SERVE_CYCLES = 1 << 15
JOB_NAMES = ["mixed", "reduction_tree", "streaming_copy", "trace:vecadd",
             "gemm_tiled", "stencil"]


def _subs() -> list:
    subs = []
    for i, name in enumerate(JOB_NAMES):
        s = {"id": f"j{i}", "workload": name}
        if not name.startswith("trace:"):
            s["scale"] = SIM_SCALE
        if i % 3 == 1:       # a config-override lane in the mix
            s["config"] = {"l2_lat": 64, "scheduler": "lrr"}
        subs.append(s)
    return subs


def _perjob_subprocess(sub: dict) -> float:
    """One job, one fresh process: build_job admission + solo simulate,
    paying interpreter start, jax import and compile — the pre-service
    cost model.  Returns the wall-clock of the whole process."""
    code = (
        "from repro.core.engine import simulate\n"
        "from repro.core.parallel import make_sm_runner\n"
        "from repro.core.plan import RunPlan\n"
        "from repro.core.service import build_job\n"
        "from repro.sim.config import TINY, split_config\n"
        f"job = build_job({sub!r}, TINY, split_config(TINY)[0], 1)\n"
        "for w, cfg in job.pairs:\n"
        "    simulate(w, cfg, make_sm_runner(cfg, 'vmap'),\n"
        f"             plan=RunPlan(max_cycles={SERVE_CYCLES}))\n"
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    t0 = time.perf_counter()
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=1800)
    dt = time.perf_counter() - t0
    if out.returncode != 0:
        raise RuntimeError(f"per-job worker failed: {out.stderr[-2000:]}")
    return dt


def run() -> list:
    from repro.core.plan import RunPlan
    from repro.core.service import SimService
    from repro.core.sweep import clear_aot_cache
    from repro.sim.config import TINY

    plan = RunPlan(max_cycles=SERVE_CYCLES, bucket_by="shape")
    subs = _subs()
    n = len(subs)

    # -- cold: a fresh server compiles its buckets on the first batch ----
    clear_aot_cache()
    svc = SimService(base=TINY, plan=plan, start=False)
    t0 = time.perf_counter()
    for s in subs:
        svc.submit(s)
    while svc.run_pending():
        pass
    cold_s = time.perf_counter() - t0

    # -- warm, threaded: the production shape — jobs/sec and latency ----
    warm_svc = SimService(base=TINY, plan=plan, batch_lanes=4,
                          max_wait_s=0.01, start=True)
    t0 = time.perf_counter()
    jobs = [warm_svc.submit(s) for s in subs]
    assert warm_svc.drain(timeout=600.0), warm_svc.stats()
    warm_s = time.perf_counter() - t0
    warm_svc.shutdown(drain=False)
    lat = [j.latency()["total_s"] for j in jobs]
    p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
    jobs_per_s = n / max(warm_s, 1e-9)

    # -- one-process-per-job baseline vs warm server, same K jobs -------
    k = max(1, int(os.environ.get("REPRO_SERVE_PERJOB_JOBS", "3")))
    ratio_subs = subs[:k]
    perjob_s = sum(_perjob_subprocess(s) for s in ratio_subs)
    t0 = time.perf_counter()
    for s in ratio_subs:
        svc.submit(s)
    while svc.run_pending():
        pass
    server_k_s = time.perf_counter() - t0
    speedup = perjob_s / max(server_k_s, 1e-9)

    save_json("serving", {
        "speedup": round(speedup, 3),
        "jobs": n, "ratio_jobs": k,
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 3),
        "jobs_per_s_warm": round(jobs_per_s, 3),
        "p50_s": round(p50, 4), "p99_s": round(p99, 4),
        "perjob_s": round(perjob_s, 3),
        "server_k_s": round(server_k_s, 3),
    })
    us = 1e6
    return [
        {"name": "serve_cold_batch", "us_per_call": cold_s / n * us,
         "derived": f"{n} jobs, compile included"},
        {"name": "serve_warm_batch", "us_per_call": warm_s / n * us,
         "derived": f"{jobs_per_s:.2f} jobs/s, p50 {p50:.3f}s, "
                    f"p99 {p99:.3f}s"},
        {"name": "one_process_per_job", "us_per_call": perjob_s / k * us,
         "derived": f"{k} fresh processes"},
        {"name": "server_vs_perjob", "us_per_call": server_k_s / k * us,
         "derived": f"{speedup:.1f}x warm server vs per-job"},
    ]


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
