"""Packing throughput: did the batching bet pay off?

Three ways to run the same (workload × config) grid, all timed in one
process so machine speed cancels:

  · ``loop``      — W jitted solo programs (dyn traced, so each workload
    compiles once for all its configs), W×C sequential dispatches;
  · ``monolithic``— the pre-PR-8 batched grid: every workload padded to
    the GLOBAL max shape, one program, every lane riding the longest
    lane's while_loop (the 0.62× loser the reference file used to pin);
  · ``bucketed``  — shape-bucketed ragged packing with early exit
    (core/batch.py:bucket_workloads + concat_workloads): one program per
    bucket, each padded only to ITS max, entry-converged padding kernels
    charging zero quanta.

The headline number — ``speedup`` in experiments/bench/packing.json, what
``run.py --gate`` pins — is bucketed-vs-loop: ≥1.0 means one-program
batching beats a loop of solo programs on the heterogeneous zoo grid, on
a single CPU device, which is the bet the ROADMAP recorded.

A second pair of rows prices the compile cache: the bucketed grid's
cold lower+compile wall vs a warm re-run through the in-process AOT
executable cache (core/sweep.py:timed_call) — warm must be ~pure
execution (compile_s == 0).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (MAX_CYCLES, SIM_SCALE, grid_workload_names,
                               save_json, timeit)
from repro.core.batch import (bucket_workloads, check_workload_fits,
                              concat_workloads, stack_kernels,
                              stack_workloads)
from repro.core.engine import run_workload_stacked
from repro.core.parallel import make_sm_runner
from repro.core.plan import RunPlan
from repro.core.sweep import (aot_cache_key, batched_init, clear_aot_cache,
                              make_grid_runner, stack_dyn, timed_call)
from repro.launch.dse import default_grid
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.sim.workloads import resolve_workload

N_WORKLOADS = 4
N_CONFIGS = 4
MAX_BUCKETS = 3


def run() -> list[dict]:
    names = grid_workload_names(N_WORKLOADS)
    workloads = [resolve_workload(
        n, scale=1.0 if n.startswith("trace:") else SIM_SCALE)
        for n in names]
    cfgs = default_grid(TINY, N_CONFIGS)
    scfg, dyn_batch = stack_dyn(cfgs)
    for w in workloads:
        check_workload_fits(scfg, w)
    max_cycles = min(MAX_CYCLES, 1 << 15)
    n_w = len(workloads)
    lanes = n_w * N_CONFIGS
    plan = RunPlan(max_cycles=max_cycles, bucket_by="shape",
                   max_buckets=MAX_BUCKETS, layout="ragged")

    # -- loop: W solo programs, W×C sequential dispatches -------------------
    sm_runner = make_sm_runner(scfg, "vmap")
    solos = []
    for w in workloads:
        wk = stack_kernels([k.pack() for k in w.kernels])
        solos.append(jax.jit(
            lambda dyn, wk=wk: run_workload_stacked(
                init_state(scfg), wk, scfg, dyn, sm_runner, max_cycles)))
    dyns = [split_config(cfg)[1] for cfg in cfgs]

    def loop():
        outs = [solo(d)["ctrl"]["total_cycles"]
                for solo in solos for d in dyns]
        jax.block_until_ready(outs)

    t_loop = timeit(loop, warmup=1, iters=3)

    # -- monolithic: one program, global max padding ------------------------
    # the grid runner DONATES its state batch, so every call builds a fresh
    # one (a broadcast + copy — the same price a real grid_sweep pays)
    runner = make_grid_runner(scfg, max_cycles=max_cycles)
    mono = stack_workloads(workloads)
    t_mono = timeit(
        lambda: jax.block_until_ready(runner(
            batched_init(scfg, n_w, N_CONFIGS), mono, dyn_batch)),
        warmup=1, iters=3)

    # -- bucketed: shape buckets, ragged layout, early exit -----------------
    groups = bucket_workloads(workloads, by=plan.bucket_by,
                              max_buckets=plan.max_buckets)
    stacks = [concat_workloads([workloads[i] for i in g]) for g in groups]

    # compile cache, cold vs warm: a fresh AOT-lower+compile of every
    # bucket program vs a re-run through the executable cache
    clear_aot_cache()
    key = aot_cache_key(scfg, plan, "grid")

    def buckets_timed():
        compile_s, execute_s = 0.0, 0.0
        status = set()
        for g, s in zip(groups, stacks):
            _, tm = timed_call(runner, batched_init(scfg, len(g), N_CONFIGS),
                               s, dyn_batch, n_lanes=lanes, cache_key=key)
            compile_s += tm["compile_s"] or 0.0
            execute_s += tm["execute_s"]
            status.add(tm.get("aot_cache", "none"))
        return compile_s, execute_s, "+".join(sorted(status))

    t0 = time.perf_counter()
    cold_compile, _, cold_status = buckets_timed()
    t_cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm_compile, _, warm_status = buckets_timed()
    t_warm_wall = time.perf_counter() - t0

    # steady-state bucketed execution (programs compiled above)
    def bucketed():
        outs = [runner(batched_init(scfg, len(g), N_CONFIGS), s,
                       dyn_batch)["ctrl"]["total_cycles"]
                for g, s in zip(groups, stacks)]
        jax.block_until_ready(outs)

    t_buck = timeit(bucketed, warmup=1, iters=3)

    # -- donation probe: is the state batch really not copied? --------------
    # donate=True must free the input buffers (the output aliases them →
    # peak live state is 1×); donate=False keeps input AND output live
    # (2×).  Results must be bit-identical either way.
    def live_mb(*trees):
        return sum(x.nbytes for t in trees
                   for x in jax.tree_util.tree_leaves(t)
                   if not x.is_deleted()) / 1e6

    runner_nd = make_grid_runner(scfg, max_cycles=max_cycles, donate=False)
    st_d = batched_init(scfg, n_w, N_CONFIGS)
    state_mb = live_mb(st_d)
    out_d = jax.block_until_ready(runner(st_d, mono, dyn_batch))
    donate_live = live_mb(st_d, out_d)
    st_nd = batched_init(scfg, n_w, N_CONFIGS)
    out_nd = jax.block_until_ready(runner_nd(st_nd, mono, dyn_batch))
    nodonate_live = live_mb(st_nd, out_nd)
    donation_freed = all(x.is_deleted()
                         for x in jax.tree_util.tree_leaves(st_d))
    bit_exact = all(
        (a == b).all() for a, b in zip(
            jax.tree_util.tree_leaves(jax.device_get(out_d)),
            jax.tree_util.tree_leaves(jax.device_get(out_nd))))
    assert donation_freed, "donated state batch was NOT freed (copied?)"
    assert bit_exact, "donated vs undonated grid results differ"

    speedup_vs_loop = t_loop / t_buck
    rows = [{
        "name": f"packing/loop_{n_w}x{N_CONFIGS}",
        "us_per_call": t_loop * 1e6,
        "derived": f"lanes_per_s={lanes / t_loop:.2f}",
    }, {
        "name": f"packing/monolithic_{n_w}x{N_CONFIGS}",
        "us_per_call": t_mono * 1e6,
        "derived": (f"lanes_per_s={lanes / t_mono:.2f} "
                    f"vs_loop={t_loop / t_mono:.2f}x"),
    }, {
        "name": (f"packing/bucketed_{n_w}x{N_CONFIGS}"
                 f"_b{len(groups)}_ragged"),
        "us_per_call": t_buck * 1e6,
        "derived": (f"lanes_per_s={lanes / t_buck:.2f} "
                    f"vs_loop={speedup_vs_loop:.2f}x "
                    f"vs_monolithic={t_mono / t_buck:.2f}x"),
    }, {
        "name": "packing/compile_cold",
        "us_per_call": t_cold_wall * 1e6,
        "derived": f"compile_s={cold_compile:.2f} aot={cold_status}",
    }, {
        "name": "packing/compile_warm",
        "us_per_call": t_warm_wall * 1e6,
        "derived": f"compile_s={warm_compile:.2f} aot={warm_status}",
    }, {
        "name": f"packing/donation_{n_w}x{N_CONFIGS}",
        "us_per_call": 0.0,
        "derived": (f"state_mb={state_mb:.2f} "
                    f"live_donate_mb={donate_live:.2f} "
                    f"live_nodonate_mb={nodonate_live:.2f} "
                    f"freed={donation_freed} bit_exact={bit_exact}"),
    }]
    save_json("packing", {
        "n_workloads": n_w, "n_configs": N_CONFIGS, "workloads": names,
        "scale": SIM_SCALE, "max_cycles": max_cycles,
        "plan": plan.describe(), "n_buckets": len(groups),
        "buckets": [[names[i] for i in g] for g in groups],
        "t_loop_s": t_loop, "t_monolithic_s": t_mono,
        "t_bucketed_s": t_buck,
        "compile_cold_s": cold_compile, "compile_warm_s": warm_compile,
        "speedup": speedup_vs_loop,
        "speedup_monolithic": t_loop / t_mono,
        "donation": {
            "state_mb": state_mb, "live_donate_mb": donate_live,
            "live_nodonate_mb": nodonate_live,
            "freed": donation_freed, "bit_exact": bit_exact,
        },
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
