"""Mesh-shape throughput: configs/sec of a distributed grid sweep vs the
2-D ('cfg', 'sm') mesh shape (core/distribute.py).

Each mesh shape runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=<A*B>`` — jax locks the
host device count at first init, so forcing it per shape is the only way
to sweep shapes from one driver (same recipe as fig5's shard workers; see
benchmarks/README.md).  This container has one physical core, so forced
host devices time-slice it: the numbers establish the *trajectory
harness* (BENCH_mesh.json artifacts in CI) and prove every shape runs;
real scaling needs real devices.  Lane results are bit-exact at every
shape regardless (tests/test_mesh_sweep.py), so the cheap shapes here are
trustworthy stand-ins for the expensive ones.

  python -m benchmarks.mesh_sweep                 # driver: sweep shapes
  python -m benchmarks.mesh_sweep --worker 2 2    # one shape (subprocess)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import REPO, SIM_SCALE, save_json

MESH_SHAPES = ((1, 1), (2, 1), (1, 2), (2, 2), (4, 1))
N_WORKLOADS = 2
N_CONFIGS = 4
MAX_CYCLES = 1 << 14


def bench_one(n_cfg: int, n_sm: int) -> dict:
    """One grid sweep on one mesh shape: build the compiled runner ONCE,
    then time repeated calls of it — ``grid_sweep()`` itself builds a
    fresh jit closure per call, so timing it would re-pay compilation
    every iteration and report compile-dominated noise as throughput."""
    import jax

    from repro.core import distribute
    from repro.core.batch import stack_workloads
    from repro.core.sweep import batched_init, make_grid_runner, stack_dyn
    from repro.launch.dse import default_grid
    from repro.sim.config import TINY
    from repro.sim.workloads import zoo_names, zoo_workload

    workloads = [zoo_workload(n, scale=SIM_SCALE)
                 for n in zoo_names()[:N_WORKLOADS]]
    cfgs = default_grid(TINY, N_CONFIGS)
    scfg, dyn_batch = stack_dyn(cfgs)
    stacked = stack_workloads(workloads)
    mesh = None
    if (n_cfg, n_sm) == (1, 1):
        runner = make_grid_runner(scfg, max_cycles=MAX_CYCLES)
    else:
        mesh = distribute.make_mesh(n_cfg, n_sm)
        distribute.check_mesh(mesh, scfg, len(cfgs))
        dyn_batch = distribute.place_lanes(dyn_batch, mesh)
        stacked = distribute.place_lanes(
            stacked, mesh, jax.sharding.PartitionSpec())
        runner = distribute.make_dist_grid_runner(scfg,
                                                  max_cycles=MAX_CYCLES,
                                                  mesh=mesh)

    def fresh_state():
        # the runners DONATE the state batch, so every call gets its own
        st = batched_init(scfg, N_WORKLOADS, N_CONFIGS)
        if mesh is not None:
            st = distribute.place_state(st, mesh, None, distribute.CFG_AXIS)
        return st

    t0 = time.perf_counter()
    state = jax.block_until_ready(runner(fresh_state(), stacked, dyn_batch))
    compile_and_run = time.perf_counter() - t0
    t0 = time.perf_counter()
    state = jax.block_until_ready(runner(fresh_state(), stacked, dyn_batch))
    wall = time.perf_counter() - t0
    lanes = N_WORKLOADS * N_CONFIGS
    return {
        "mesh": [n_cfg, n_sm], "lanes": lanes, "wall_s": wall,
        "compile_s": max(0.0, compile_and_run - wall),
        "lanes_per_s": lanes / max(wall, 1e-9),
        "cycles_check": int(state["ctrl"]["total_cycles"].sum()),
    }


def worker(n_cfg: int, n_sm: int) -> None:
    """Runs inside the subprocess with the forced device count."""
    print(json.dumps(bench_one(n_cfg, n_sm)))


def run_mesh_worker(n_cfg: int, n_sm: int, timeout: int = 1200) -> dict:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_cfg * n_sm}",
        PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.mesh_sweep",
         "--worker", str(n_cfg), str(n_sm)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"mesh worker failed: {out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(shapes=MESH_SHAPES, fast: bool = False) -> list[dict]:
    if fast:  # honor run.py --fast: NO subprocess sweeps — just the
        shapes = ((1, 1),)  # in-process single-device anchor
    rows = []
    results = {}
    checks = set()
    for a, b in shapes:
        try:
            r = bench_one(a, b) if fast else run_mesh_worker(a, b)
            results[f"{a}x{b}"] = r
            checks.add(r["cycles_check"])
            us = r["wall_s"] * 1e6
            derived = (f"lanes_per_s={r['lanes_per_s']:.2f};"
                       f"compile_s={r['compile_s']:.1f}")
        except Exception as e:  # noqa: BLE001
            us = -1.0
            derived = f"err:{type(e).__name__}"
        rows.append({"name": f"mesh/grid_{a}x{b}",
                     "us_per_call": us, "derived": derived})
    # every shape must agree on total simulated cycles (cheap cross-check;
    # the bit-exact per-lane lock lives in tests/test_mesh_sweep.py)
    assert len(checks) <= 1, f"mesh shapes disagree on cycles: {results}"
    save_json("mesh_sweep", {
        "n_workloads": N_WORKLOADS, "n_configs": N_CONFIGS,
        "scale": SIM_SCALE, "max_cycles": MAX_CYCLES, "results": results,
    })
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        i = sys.argv.index("--worker")
        worker(int(sys.argv[i + 1]), int(sys.argv[i + 2]))
    else:
        for row in run(fast="--fast" in sys.argv):
            print(row)
