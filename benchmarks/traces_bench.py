"""Real-trace ingestion benchmark (BENCH_traces.json).

Three numbers for the trace front-end (sim/traceio.py):

  traces/ingest      — parse + address-fit + lower time for every
                       bundled fixture (the front-end's fixed cost; it
                       runs once per trace, off the compiled path)
  traces/grid_trace  — (trace workloads × C configs) grid_sweep
                       lanes/sec: trace-derived rows through the SAME
                       batched path the synthetic zoo uses
  traces/grid_zoo    — an equally-sized synthetic grid for comparison
                       (same lane count, zoo workloads)

The comparison prices what real-app rows cost relative to synthetic
rows in the batched program — trace kernels are typically shorter but
less regular, so the straggler tax differs.
"""
from __future__ import annotations

import os

import jax

from benchmarks.common import MAX_CYCLES, REPO, SIM_SCALE, save_json, timeit
from repro.core.sweep import grid_sweep
from repro.launch.dse import default_grid
from repro.sim import traceio
from repro.sim.config import TINY
from repro.sim.workloads import zoo_names, zoo_workload

TRACE_DIR = os.path.join(REPO, "tests", "data", "traces")
N_CONFIGS = 2


def run() -> list[dict]:
    files = traceio.trace_files(TRACE_DIR)

    def ingest():
        return [traceio.load_trace(f) for f in files]

    t_ingest = timeit(ingest, warmup=1, iters=5)
    ingests = ingest()
    trace_ws = [ing.workload for ing in ingests]
    cfgs = default_grid(TINY, N_CONFIGS)
    max_cycles = min(MAX_CYCLES, 1 << 15)
    lanes = len(trace_ws) * N_CONFIGS

    def grid(ws):
        return jax.block_until_ready(
            grid_sweep(ws, cfgs, max_cycles=max_cycles).state)

    t_trace = timeit(lambda: grid(trace_ws), warmup=1, iters=3)
    zoo_ws = [zoo_workload(n, scale=SIM_SCALE)
              for n in zoo_names()[:len(trace_ws)]]
    t_zoo = timeit(lambda: grid(zoo_ws), warmup=1, iters=3)

    rows = [{
        "name": f"traces/ingest_{len(files)}files",
        "us_per_call": t_ingest * 1e6,
        "derived": f"traces_per_s={len(files) / t_ingest:.1f}",
    }, {
        "name": f"traces/grid_trace_{len(trace_ws)}x{N_CONFIGS}",
        "us_per_call": t_trace * 1e6,
        "derived": f"lanes_per_s={lanes / t_trace:.2f}",
    }, {
        "name": f"traces/grid_zoo_{len(zoo_ws)}x{N_CONFIGS}",
        "us_per_call": t_zoo * 1e6,
        "derived": (f"lanes_per_s={lanes / t_zoo:.2f} "
                    f"trace_vs_zoo={t_zoo / t_trace:.2f}x"),
    }]
    save_json("traces_bench", {
        "files": [os.path.basename(f) for f in files],
        "n_configs": N_CONFIGS, "max_cycles": max_cycles,
        "t_ingest_s": t_ingest, "t_grid_trace_s": t_trace,
        "t_grid_zoo_s": t_zoo,
        "fit_err_max": max((f.fit_err_max for ing in ingests
                            for f in ing.fits), default=0.0),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
