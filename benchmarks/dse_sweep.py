"""DSE throughput: batched vmap sweep vs a Python loop of solo runs.

The batched path compiles ONE program for N configs (one device dispatch
per quantum for ALL lanes); the loop path gets the same compilation
amortization (dyn is a traced argument of one shared jitted solo program —
the static/dynamic split's other payoff) but pays N sequential device
programs.  Reports configs/sec for both and the speedup — the DSE analogue
of the paper's Fig. 5.
"""
from __future__ import annotations

import jax

from benchmarks.common import MAX_CYCLES, SIM_SCALE, save_json, timeit
from repro.core.batch import stack_kernels
from repro.core.engine import run_workload
from repro.core.parallel import make_sm_runner
from repro.core.sweep import batched_init, make_sweep_runner, stack_dyn
from repro.launch.dse import default_grid
from repro.sim.config import TINY, split_config
from repro.sim.state import init_state
from repro.workloads import make_workload

N_CONFIGS = 8
BENCH = "hotspot"


def run() -> list[dict]:
    w = make_workload(BENCH, scale=SIM_SCALE)
    cfgs = default_grid(TINY, N_CONFIGS)
    scfg, dyn_batch = stack_dyn(cfgs)
    packed = [k.pack() for k in w.kernels]
    stacked = stack_kernels(packed)
    max_cycles = min(MAX_CYCLES, 1 << 15)

    # the batched runner DONATES its state argument, so every timed call
    # builds a fresh batch (included in the measured time — real runs pay
    # the same init)
    batched = make_sweep_runner(scfg, max_cycles=max_cycles)
    t_batch = timeit(
        lambda: jax.block_until_ready(
            batched(batched_init(scfg, N_CONFIGS), stacked, dyn_batch)),
        warmup=1, iters=3)

    runner = make_sm_runner(scfg, "vmap")
    solo = jax.jit(lambda dyn: run_workload(
        init_state(scfg), packed, scfg, dyn, runner, max_cycles))
    dyns = [split_config(cfg)[1] for cfg in cfgs]

    def loop():
        outs = [solo(d)["ctrl"]["total_cycles"] for d in dyns]
        jax.block_until_ready(outs)
        return outs

    t_loop = timeit(loop, warmup=1, iters=3)

    rows = [{
        "name": f"dse/batched_x{N_CONFIGS}",
        "us_per_call": t_batch * 1e6,
        "derived": f"configs_per_s={N_CONFIGS / t_batch:.2f}",
    }, {
        "name": f"dse/loop_x{N_CONFIGS}",
        "us_per_call": t_loop * 1e6,
        "derived": (f"configs_per_s={N_CONFIGS / t_loop:.2f} "
                    f"speedup={t_loop / t_batch:.2f}x"),
    }]
    save_json("dse_sweep", {
        "n_configs": N_CONFIGS, "bench": BENCH, "scale": SIM_SCALE,
        "max_cycles": max_cycles, "t_batched_s": t_batch, "t_loop_s": t_loop,
        "speedup": t_loop / t_batch,
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
