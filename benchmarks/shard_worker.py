"""Subprocess worker: one sharded simulation at a fixed device count.

Prints a single JSON line: wall time, cycles, and the comparable-stats
digest (for cross-process determinism checks).
"""
import argparse
import json
import os
import sys
import time

# XLA_FLAGS must be set by the parent before jax import
import jax
from functools import partial

from repro.core import stats as S
from repro.core.engine import run_workload
from repro.core.parallel import (permute_state, run_kernel_sharded,
                                 sm_permutation)
from repro.launch.mesh import make_host_mesh
from repro.sim.config import RTX3080TI, split_config
from repro.sim.state import init_state
from repro.workloads import make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", required=True)
    ap.add_argument("--devices", type=int, required=True)
    ap.add_argument("--policy", default="static")
    ap.add_argument("--exchange", default="window")
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--max-cycles", type=int, default=1 << 17)
    args = ap.parse_args()

    cfg = RTX3080TI
    w = make_workload(args.workload, scale=args.scale)
    mesh = make_host_mesh(args.devices, "sm")
    perm = sm_permutation(cfg, args.devices, args.policy)

    runner = jax.jit(partial(run_kernel_sharded, cfg=cfg, mesh=mesh,
                             max_cycles=args.max_cycles,
                             exchange=args.exchange))

    scfg, dyn = split_config(cfg)
    packed = [k.pack() for k in w.kernels]

    def run_all():
        state = run_workload(
            permute_state(init_state(cfg), perm), packed, scfg, dyn,
            kernel_runner=lambda st, k, d: runner(st, k, dyn=d))
        jax.block_until_ready(state["ctrl"]["total_cycles"])
        return state

    state = run_all()          # compile + warmup
    t0 = time.perf_counter()
    state = run_all()
    wall = time.perf_counter() - t0

    out = S.finalize(state)
    comp = S.comparable(out)
    # per-device work balance (for the modeled-speedup / scheduler figures)
    per_sm = out["warp_cycles_per_sm"]
    chunks = per_sm.reshape(args.devices, -1).sum(axis=1)
    print(json.dumps({
        "workload": args.workload, "devices": args.devices,
        "policy": args.policy, "exchange": args.exchange,
        "wall_s": wall, "stats": {k: int(v) for k, v in comp.items()},
        "per_device_work": [int(x) for x in chunks],
    }))


if __name__ == "__main__":
    main()
