"""Fig. 1 analogue — single-thread simulation time per workload.

Reference mode = sequential (lax.map over SMs), measured on this host.
Workloads are uniformly scaled (see workloads/synthetic.py); the figure's
*shape* — which applications are expensive to simulate — is the deliverable.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import DEFAULT_BENCHES, MAX_CYCLES, SIM_SCALE, save_json
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import make_workload


def run(benches=None) -> list[dict]:
    cfg = RTX3080TI
    rows = []
    runner = make_sm_runner(cfg, "seq")
    for name in benches or DEFAULT_BENCHES:
        w = make_workload(name, scale=SIM_SCALE)
        t0 = time.perf_counter()
        st = simulate(w, cfg, runner, max_cycles=MAX_CYCLES)
        jax.block_until_ready(st["ctrl"]["total_cycles"])
        wall = time.perf_counter() - t0
        out = S.finalize(st)
        rows.append({"name": f"fig1/{name}", "us_per_call": wall * 1e6,
                     "derived": f"cycles={out['cycles']};ipc={out['ipc']};"
                                f"ctas={out['ctas_launched']}"})
    save_json("fig1_sim_time", {"rows": rows})
    return rows
