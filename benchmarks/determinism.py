"""Determinism table — the paper's headline accuracy claim (0% deviation).

Runs one workload under every execution mode / device count / scheduler /
exchange policy and asserts the comparable-stats digest is IDENTICAL
(paper: parallel == sequential, unlike GpuTejas' 7.7% / Lee et al.'s 3%).
"""
from __future__ import annotations

from benchmarks.common import MAX_CYCLES, SIM_SCALE, run_shard_worker, \
    save_json
from repro.core import stats as S
from repro.core.engine import simulate
from repro.core.parallel import make_sm_runner
from repro.sim.config import RTX3080TI
from repro.workloads import make_workload


def run(workload: str = "sssp") -> list[dict]:
    cfg = RTX3080TI
    w = make_workload(workload, scale=SIM_SCALE)
    ref = S.comparable(S.finalize(
        simulate(w, cfg, make_sm_runner(cfg, "seq"), max_cycles=MAX_CYCLES)))
    digest = tuple(sorted(ref.items()))
    rows = []
    vm = S.comparable(S.finalize(
        simulate(w, cfg, make_sm_runner(cfg, "vmap"), max_cycles=MAX_CYCLES)))
    rows.append({"name": f"determinism/{workload}/vmap", "us_per_call": 0.0,
                 "derived": "identical" if tuple(sorted(vm.items())) == digest
                 else "MISMATCH"})
    for d in (2, 8, 16):
        for policy in ("static", "dynamic"):
            for exchange in (("window", "cycle") if d == 8 else ("window",)):
                r = run_shard_worker(workload, d, policy, exchange)
                ok = tuple(sorted(r["stats"].items())) == digest
                rows.append({
                    "name": f"determinism/{workload}/d{d}/{policy}/{exchange}",
                    "us_per_call": r["wall_s"] * 1e6,
                    "derived": "identical" if ok else "MISMATCH",
                })
    assert all("MISMATCH" not in r["derived"] for r in rows), rows
    save_json("determinism", {"rows": rows, "ref": ref})
    return rows
